"""Unified observability layer (repro.obs).

Covers the ISSUE-10 battery: the shared monotonic clock (and its adoption
by every threaded runtime module), the span tracer (nesting, thread-local
stacks, disabled no-op), Chrome-trace export + schema validation (paired
B/E, non-overlapping siblings, coverage), the metrics registry
(counters/gauges/histograms/providers), the LogHistogram torn-snapshot
concurrency regression, the structured event log and its JSON-lines sink,
StreamMonitor window attribution, overlap_report steady-state fractions
under injected slow/fast transfers, and a traced CPSolver run whose span
tree nests sweep -> mode_update -> {ec, exchange} at >= 95% coverage with
fits bitwise identical to the untraced path.
"""
import json
import threading
import time

import numpy as np
import pytest

import repro.api as api
from repro import obs
from repro.api.config import DecomposeConfig, RuntimeConfig
from repro.api.solver import CPSolver
from repro.obs import clock
from repro.obs import trace as obs_trace
from repro.obs.export import (chrome_trace, dump_chrome_trace, span_counts,
                              validate_trace)
from repro.obs.metrics import EventLog, LogHistogram, MetricsRegistry
from repro.obs.profiler import StreamMonitor


@pytest.fixture(autouse=True)
def _fresh_obs():
    """Every test starts from a disabled tracer and a clean global
    registry, and cannot leak an enabled tracer into other test files."""
    obs.reset()
    yield
    obs.reset()


# -- clock -------------------------------------------------------------------

def test_clock_monotonic_and_wall():
    ts = [clock.now() for _ in range(100)]
    assert all(b >= a for a, b in zip(ts, ts[1:]))
    assert abs(clock.walltime() - time.time()) < 5.0


def test_threaded_runtime_modules_share_the_obs_clock():
    """Satellite: sparse/stream, serve/batcher, schedule/rebalance and
    training/checkpoint all time against repro.obs.clock — not their own
    perf_counter bindings."""
    from repro.schedule import rebalance
    from repro.serve import batcher
    from repro.sparse import stream
    from repro.training import checkpoint
    for mod in (stream, batcher, rebalance, checkpoint):
        assert mod.clock is clock, mod.__name__


# -- tracer ------------------------------------------------------------------

def test_disabled_span_is_shared_noop():
    tracer = obs_trace.get_tracer()
    assert not tracer.enabled
    s1 = tracer.span("a", mode=1)
    s2 = tracer.span("b")
    assert s1 is s2  # one shared null object, no per-call allocation
    with s1:
        pass
    assert tracer.records() == []


def test_timed_measures_even_when_disabled():
    with obs_trace.timed("work") as t:
        time.sleep(0.01)
    assert t.duration >= 0.009
    assert obs_trace.get_tracer().records() == []


def test_span_nesting_and_attrs():
    obs_trace.enable()
    with obs_trace.span("outer", sweep=1):
        with obs_trace.span("inner", mode=2):
            pass
        with obs_trace.span("inner", mode=3):
            pass
    recs = {}
    for r in obs_trace.get_tracer().records():
        recs.setdefault(r["name"], []).append(r)
    outer, = recs["outer"]
    assert outer["parent"] is None and outer["attrs"] == {"sweep": 1}
    inner = recs["inner"]
    assert [r["parent"] for r in inner] == [outer["id"], outer["id"]]
    assert [r["attrs"]["mode"] for r in inner] == [2, 3]
    # children recorded before the parent (completion order), inside it
    for r in inner:
        assert outer["t0"] <= r["t0"] <= r["t1"] <= outer["t1"]
    summary = obs_trace.get_tracer().summary()
    assert summary["inner"]["count"] == 2
    assert summary["outer"]["count"] == 1


def test_span_stacks_are_thread_local():
    obs_trace.enable()
    started = threading.Event()
    release = threading.Event()

    def worker():
        with obs_trace.span("worker_root"):
            started.set()
            release.wait(5)

    t = threading.Thread(target=worker, name="obs-worker")
    with obs_trace.span("main_root"):
        t.start()
        started.wait(5)
        release.set()
        t.join()
    recs = {r["name"]: r for r in obs_trace.get_tracer().records()}
    # the worker's span roots its own thread's tree — it must not have
    # nested under the main thread's open span
    assert recs["worker_root"]["parent"] is None
    assert recs["worker_root"]["tid"] != recs["main_root"]["tid"]
    assert recs["worker_root"]["thread"] == "obs-worker"


# -- export + validation -----------------------------------------------------

def _demo_records():
    obs_trace.enable()
    with obs_trace.span("run"):
        for k in range(2):
            with obs_trace.span("sweep", sweep=k):
                with obs_trace.span("ec"):
                    pass
    return obs_trace.get_tracer().records()


def test_chrome_trace_pairs_and_nests():
    records = _demo_records()
    trace = chrome_trace(records, pid=1)
    evs = [e for e in trace["traceEvents"] if e["ph"] in "BE"]
    # DFS order: run.B sweep.B ec.B ec.E sweep.E sweep.B ec.B ec.E sweep.E run.E
    assert [(e["ph"], e["name"]) for e in evs] == [
        ("B", "run"), ("B", "sweep"), ("B", "ec"), ("E", "ec"),
        ("E", "sweep"), ("B", "sweep"), ("B", "ec"), ("E", "ec"),
        ("E", "sweep"), ("E", "run")]
    # B events carry the span attrs; one thread_name metadata event
    assert evs[1]["args"] == {"sweep": 0}
    meta = [e for e in trace["traceEvents"] if e["ph"] == "M"]
    assert len(meta) == 1 and meta[0]["name"] == "thread_name"
    assert span_counts(records) == {"run": 1, "sweep": 2, "ec": 2}


def test_validate_trace_accepts_good_rejects_broken():
    good = chrome_trace(_demo_records(), pid=1)
    res = validate_trace(good)
    assert res["ok"] and res["coverage"] > 0.99, res

    # unpaired B: drop the final E
    broken = {"traceEvents": good["traceEvents"][:-2]}
    res = validate_trace(broken)
    assert not res["ok"]
    assert any("never closed" in p for p in res["problems"])

    # overlapping siblings
    tids = {"pid": 1, "tid": 7}
    res = validate_trace({"traceEvents": [
        {"name": "p", "ph": "B", "ts": 0.0, **tids},
        {"name": "a", "ph": "B", "ts": 1.0, **tids},
        {"name": "a", "ph": "E", "ts": 50.0, **tids},
        {"name": "b", "ph": "B", "ts": 10.0, **tids},
        {"name": "b", "ph": "E", "ts": 60.0, **tids},
        {"name": "p", "ph": "E", "ts": 100.0, **tids},
    ]})
    assert not res["ok"]
    assert any("overlaps the previous sibling" in p for p in res["problems"])

    # top-level coverage below threshold
    res = validate_trace({"traceEvents": [
        {"name": "a", "ph": "B", "ts": 0.0, **tids},
        {"name": "a", "ph": "E", "ts": 10.0, **tids},
        {"name": "b", "ph": "B", "ts": 90.0, **tids},
        {"name": "b", "ph": "E", "ts": 100.0, **tids},
    ]}, min_coverage=0.95)
    assert not res["ok"] and res["coverage"] < 0.25
    assert any("coverage" in p for p in res["problems"])


def test_validator_cli_expectations(tmp_path):
    from repro.obs.__main__ import main
    path = str(tmp_path / "t.json")
    dump_chrome_trace(path, _demo_records())
    assert main([path, "--expect-span", "sweep=2",
                 "--expect-span", "ec"]) == 0
    assert main([path, "--expect-span", "sweep=3"]) == 1
    assert main([path, "--expect-span", "exchange"]) == 1


# -- metrics registry --------------------------------------------------------

def test_registry_counters_gauges_latency():
    reg = MetricsRegistry()
    reg.inc("q"), reg.inc("q", 4)
    reg.set_gauge("depth", 3)
    reg.observe("op", 0.01)
    with reg.time("op"):
        pass
    assert reg.counter("q") == 5
    assert reg.counter("absent") == 0
    assert reg.gauge("depth") == 3
    lat = reg.latency("op")
    assert lat["count"] == 2 and lat["p50_ms"] is not None
    assert reg.latency("absent") is None
    snap = reg.snapshot()
    assert snap["counters"] == {"q": 5} and snap["gauges"] == {"depth": 3}


def test_registry_providers_and_reentrancy():
    """Providers run OUTSIDE the registry lock: a section builder is free
    to call back into the registry (this deadlocks if report() holds the
    lock across provider calls)."""
    reg = MetricsRegistry()

    def section():
        reg.inc("report_calls")  # reentrant mutation
        return {"ok": True}

    reg.register_provider("demo", section)
    rep = reg.report()
    assert rep["sections"] == {"demo": {"ok": True}}
    assert rep["uptime_s"] >= 0
    assert reg.counter("report_calls") == 1
    reg.unregister_provider("demo")
    assert reg.report()["sections"] == {}
    reg.unregister_provider("demo")  # idempotent


def test_log_histogram_percentile_geometry():
    h = LogHistogram()
    for _ in range(99):
        h.record(1e-3)
    h.record(1.0)
    assert h.count == 100
    # upper bucket edge: conservative, within one bucket (~26%) of truth
    assert 1e-3 <= h.percentile(0.5) <= 1.3e-3
    assert 1.0 <= h.percentile(0.995) <= 1.3
    assert LogHistogram().percentile(0.5) is None
    with pytest.raises(ValueError):
        LogHistogram(lo=1.0, hi=0.1)


def test_log_histogram_snapshot_never_torn():
    """Satellite regression: concurrent record() during snapshot() must
    never yield a torn count/bucket view. Writers record a constant, so
    every internally-consistent snapshot has mean exactly that constant
    and count == the histogram's own cumulative bucket mass."""
    h = LogHistogram()
    stop = threading.Event()
    VALUE = 1e-3

    def hammer():
        while not stop.is_set():
            h.record(VALUE)

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    try:
        for _ in range(300):
            s = h.snapshot()
            if s["count"] == 0:
                continue
            # count and total_s taken from ONE locked state: their ratio
            # is exact even while writers race
            assert s["mean_ms"] == pytest.approx(VALUE * 1e3, rel=1e-9), s
            assert s["total_s"] == pytest.approx(s["count"] * VALUE,
                                                 rel=1e-9), s
            assert 1e-3 <= s["p50_ms"] / 1e3 <= 1.3e-3, s
    finally:
        stop.set()
        for t in threads:
            t.join()


# -- event log ---------------------------------------------------------------

def test_event_log_stamps_payloads_and_sink(tmp_path):
    log = EventLog()
    log.emit("sweep", sweep=1)
    log.emit("rebalance", sweep=1, migrations=0)
    log.emit("sweep", sweep=2)
    assert len(log) == 3
    for e in log.events():
        assert e["t"] > 0 and e["wall"] > 0 and "kind" in e
    # payloads == exactly what the emitter passed (stamps stripped)
    assert log.payloads("sweep") == [{"sweep": 1}, {"sweep": 2}]
    assert log.payloads("rebalance") == [{"sweep": 1, "migrations": 0}]
    # a sink attached mid-run replays the buffered events, then mirrors
    path = str(tmp_path / "events.jsonl")
    log.set_sink(path)
    log.emit("sweep", sweep=3)
    log.close_sink()
    lines = [json.loads(x) for x in open(path).read().splitlines()]
    assert [e["kind"] for e in lines] == ["sweep", "rebalance", "sweep",
                                         "sweep"]
    assert lines[-1]["sweep"] == 3
    log.emit("sweep", sweep=4)  # post-close emission: memory only
    assert len(open(path).read().splitlines()) == 4


# -- stream monitor + overlap_report fractions -------------------------------

def test_stream_monitor_window_attribution():
    log = EventLog()
    # window A: 100 ms build, consumer blocked 100 ms (fully exposed)
    log.emit("h2d_build", build_s=0.1, bytes=10, mode=0, shard=0)
    log.emit("h2d_wait", wait_s=0.1, cold=True, mode=0, shard=0)
    # window B: 100 ms build, consumer blocked 1 ms (hidden by compute)
    log.emit("h2d_build", build_s=0.1, bytes=10, mode=0, shard=1)
    log.emit("h2d_wait", wait_s=0.001, cold=False, mode=0, shard=1)
    # wait with no recorded build (sink attached mid-run)
    log.emit("h2d_wait", wait_s=0.005, cold=False, mode=1, shard=0)
    rep = StreamMonitor(log).report()
    assert rep["num_windows"] == 3
    a, b, c = rep["windows"]
    assert a["exposed_s"] == pytest.approx(0.1)
    assert a["hidden_s"] == pytest.approx(0.0)
    assert b["hidden_s"] == pytest.approx(0.099)
    assert c["transfer_s"] == 0.0
    assert rep["stalled_windows"] == 1  # only A crossed the 50% threshold
    assert rep["transfer_s"] == pytest.approx(0.2)
    assert rep["exposed_s"] == pytest.approx(0.101)


def _sleep_streamer(build_s, events=None):
    """Minimal _StreamerBase subclass: every build sleeps a fixed time."""
    from repro.sparse.stream import _StreamerBase

    class _SleepStreamer(_StreamerBase):
        def _build(self, key):
            time.sleep(build_s)
            return np.zeros(1)

        def _key_nbytes(self, key):
            return 8

    return _SleepStreamer(prefetch=2, events=events)


def test_streamer_exposed_vs_hidden_under_slow_and_fast_transfers():
    """Injected transfer speeds drive the exposed/hidden split the
    overlap report is built on: a cold (unprefetched) slow load is fully
    exposed; a prefetched load that finishes behind 'compute' is hidden."""
    log = EventLog()
    slow = _sleep_streamer(0.05, events=log)
    try:
        slow._wait("w0")  # cold: consumer blocks for the whole build
        st = slow.stats_snapshot()
        assert st["cold_builds"] == 1
        assert st["exposed_s"] >= 0.9 * st["transfer_s"] > 0
    finally:
        slow.close()
    kinds = [e["kind"] for e in log.events()]
    assert kinds == ["h2d_build", "h2d_wait"]
    assert log.events("h2d_wait")[0]["cold"] is True

    fast = _sleep_streamer(0.05)
    try:
        fast._dispatch("w0")
        time.sleep(0.25)  # "compute" long enough to hide the transfer
        fast._wait("w0")
        st = fast.stats_snapshot()
        assert st["cold_builds"] == 0
        assert st["transfer_s"] >= 0.05
        assert st["exposed_s"] <= 0.5 * st["transfer_s"]
    finally:
        fast.close()


class _FakeStreamSolver:
    """Just enough of CPSolver for overlap_report: injected aggregate
    stats + per-sweep stream_sweep events."""

    streaming = True
    stream_events = CPSolver.stream_events  # the real stamped-view property

    def __init__(self, sweeps, budget=1 << 20):
        from types import SimpleNamespace
        self.events = EventLog()
        total_t = total_e = 0.0
        for i, (transfer, exposed) in enumerate(sweeps):
            total_t += transfer
            total_e += exposed
            self.events.emit("stream_sweep", sweep=i + 1,
                             transfer_s=transfer, exposed_s=exposed,
                             hidden_s=max(transfer - exposed, 0.0),
                             overlap_fraction=(
                                 (transfer - exposed) / transfer
                                 if transfer > 0 else None),
                             shards_streamed=4)
        snap = {"transfer_s": total_t, "exposed_s": total_e,
                "peak_resident_bytes": budget // 2, "bytes_streamed": 1000,
                "builds": 4 * len(sweeps), "cold_builds": 4,
                "spill_hits": 0, "spill_saves": 0}
        self.streamer = SimpleNamespace(stats_snapshot=lambda: dict(snap))
        self.config = SimpleNamespace(runtime=SimpleNamespace(
            memory_budget=budget, stream_buffers=2))
        self.stream_plans = [SimpleNamespace(num_shards=4, shard_bytes=100)]

    overlap_report = CPSolver.overlap_report


def test_overlap_report_steady_state_fractions():
    """Satellite: steady-state overlap drops the cold first sweep. Fast
    steady sweeps (nothing exposed) -> steady fraction 1.0 even though the
    cold sweep drags the cumulative number down; slow steady sweeps
    (every transfer exposed) -> steady fraction 0.0."""
    fast = _FakeStreamSolver([(1.0, 1.0), (1.0, 0.0), (1.0, 0.0)])
    rep = fast.overlap_report()
    assert rep["enabled"]
    assert rep["overlap_fraction_steady"] == pytest.approx(1.0)
    assert rep["overlap_fraction"] == pytest.approx(2.0 / 3.0)
    assert [e["exposed_s"] for e in rep["per_sweep"]] == [1.0, 0.0, 0.0]

    slow = _FakeStreamSolver([(1.0, 1.0), (1.0, 1.0), (1.0, 1.0)])
    rep = slow.overlap_report()
    assert rep["overlap_fraction_steady"] == pytest.approx(0.0)
    assert rep["overlap_fraction"] == pytest.approx(0.0)

    mixed = _FakeStreamSolver([(2.0, 2.0), (1.0, 0.25), (1.0, 0.25)])
    rep = mixed.overlap_report()
    assert rep["overlap_fraction_steady"] == pytest.approx(0.75)
    # one sweep so far: no steady-state number yet
    first = _FakeStreamSolver([(1.0, 0.5)])
    assert first.overlap_report()["overlap_fraction_steady"] is None


# -- traced solver run -------------------------------------------------------

def _solver_cfg(trace):
    return DecomposeConfig(rank=4, runtime=RuntimeConfig(
        num_devices=1, tol=0.0, seed=0, trace=trace))


def test_traced_run_nests_and_matches_untraced(small_tensor, tmp_path):
    """Acceptance: a traced run's Chrome trace nests run -> sweep ->
    mode_update -> {ec, exchange} at >= 95% top-level coverage, and its
    fit trajectory is bitwise identical to the untraced path."""
    cfg = _solver_cfg(False)
    with api.compile(api.plan(small_tensor, cfg), cfg) as s:
        r_plain = s.run(2)

    cfg = _solver_cfg(True)
    with api.compile(api.plan(small_tensor, cfg), cfg) as s:
        assert obs_trace.get_tracer().enabled
        r_traced = s.run(2)
        path = str(tmp_path / "trace.json")
        trace = s.dump_trace(path)
        rep = s.report()
        glob = obs.report()
        assert s._obs_name in glob["sections"]
    # close() deregistered the solver's section from the global report
    assert s._obs_name not in obs.report()["sections"]

    assert r_traced.fits == r_plain.fits
    for a, b in zip(r_plain.factors, r_traced.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    res = validate_trace(trace, min_coverage=0.95)
    assert res["ok"], res["problems"]
    assert res["coverage"] >= 0.95
    nmodes = len(small_tensor.shape)
    assert res["span_counts"]["run"] == 1
    assert res["span_counts"]["sweep"] == 2
    assert res["span_counts"]["mode_update"] == 2 * nmodes
    assert res["span_counts"]["ec"] == 2 * nmodes
    assert res["span_counts"]["exchange"] == 2 * nmodes
    assert json.load(open(path)) == trace

    # parent links: ec/exchange under mode_update, mode_update under sweep,
    # sweep under run
    by_id = {r["id"]: r for r in obs_trace.get_tracer().records()}
    parent_names = {"ec": "mode_update", "exchange": "mode_update",
                    "mode_update": "sweep", "sweep": "run"}
    for r in by_id.values():
        want = parent_names.get(r["name"])
        if want is not None:
            assert by_id[r["parent"]]["name"] == want, r

    # the solver report is the registry view over the existing reporters,
    # value-identical to calling them directly (measure=False: a report
    # snapshot must never force an HLO re-lower)
    assert rep["sections"]["overlap"] == {"enabled": False}
    assert rep["sections"]["exchange"] == s.exchange_report(measure=False)
    assert "measured" not in rep["sections"]["exchange"]
    assert rep["sections"]["imbalance"] == s.imbalance_report()


def test_solver_events_and_dumps(small_tensor, tmp_path):
    cfg = _solver_cfg(False)
    with api.compile(api.plan(small_tensor, cfg), cfg) as s:
        s.run(2)
        assert [e["sweep"] for e in s.events.payloads("sweep")] == [1, 2]
        assert s.stream_events == []  # resident run: no stream_sweep events
        path = str(tmp_path / "events.jsonl")
        s.dump_events(path)
    lines = [json.loads(x) for x in open(path).read().splitlines()]
    assert [e["kind"] for e in lines].count("sweep") == 2
    # tracer stayed disabled: no spans recorded, hot path untouched
    assert obs_trace.get_tracer().records() == []
