"""The plan/compile/execute API: config layer, plan caching, solver
execution, and the deprecated cp_decompose shim's equivalence."""
import numpy as np
import pytest

import repro.api as api
from repro.core import partition as partition_mod
from repro.core.decompose import CPResult, cp_decompose


# -- config layer -------------------------------------------------------------

def test_presets():
    paper = api.preset("paper")
    assert paper.partition.replication == 1      # no intra-group merge
    assert not paper.kernel.use_kernel
    assert paper.exchange.ring
    opt = api.preset("optimized")
    assert opt.partition.replication is None     # auto per-mode pick
    assert opt.kernel.resolved_variant() == "blocked"
    fused = api.preset("fused")
    assert fused.kernel.resolved_variant() == "fused"
    assert fused.kernel.autotune
    with pytest.raises(ValueError, match="unknown preset"):
        api.preset("nope")


def test_dotted_overrides():
    cfg = api.DecomposeConfig()
    out = cfg.with_overrides({"rank": 64, "kernel.variant": "fused",
                              "runtime.tol": 0.0})
    assert out.rank == 64
    assert out.kernel.variant == "fused"
    assert out.runtime.tol == 0.0
    assert cfg.rank == 32  # frozen: original untouched
    with pytest.raises(ValueError, match="no field"):
        cfg.with_overrides({"kernel.bogus": 1})
    with pytest.raises(ValueError, match="unknown config section"):
        cfg.with_overrides({"bogus.field": 1})
    with pytest.raises(ValueError, match="too deep"):
        cfg.with_overrides({"a.b.c": 1})
    # a whole section can be swapped for a config object, not a scalar typo
    out = cfg.with_overrides({"kernel": api.KernelConfig(use_kernel=True)})
    assert out.kernel.use_kernel
    with pytest.raises(ValueError, match="dotted path"):
        cfg.with_overrides({"kernel": "fused"})


def test_apply_set_args():
    cfg = api.apply_set_args(api.DecomposeConfig(), [
        "rank=16", "runtime.tol=1e-4", "exchange.ring=false",
        "partition.replication=none", "kernel.variant=fused"])
    assert cfg.rank == 16
    assert cfg.runtime.tol == pytest.approx(1e-4)
    assert cfg.exchange.ring is False
    # Python-style capitalization must not become a truthy string
    cfg = api.apply_set_args(cfg, ["exchange.ring=False"])
    assert cfg.exchange.ring is False
    cfg = api.apply_set_args(cfg, ["exchange.ring=True", "rank=None"])
    assert cfg.exchange.ring is True and cfg.rank is None
    assert cfg.partition.replication is None
    assert cfg.kernel.variant == "fused"
    with pytest.raises(ValueError, match="key=value"):
        api.apply_set_args(cfg, ["rank"])


def test_config_json_roundtrip():
    cfg = api.preset("fused", {"rank": 8, "runtime.checkpoint_dir": "/tmp/x"})
    back = api.DecomposeConfig.from_json(cfg.to_json())
    assert back == cfg


def test_kernel_kwargs_resolution():
    from repro.kernels import ops as kops
    kw = api.KernelConfig(use_kernel=False).mttkrp_kwargs()
    assert kw == {"use_kernel": False, "variant": "ref", "num_buffers": 2}
    kw = api.KernelConfig(use_kernel=True, variant="fused",
                          num_buffers=3).mttkrp_kwargs()
    assert kw == {"use_kernel": True, "variant": "fused", "num_buffers": 3}
    # the helper and the config agree (same resolution point)
    assert kw == kops.kernel_kwargs_from_config(
        api.KernelConfig(use_kernel=True, variant="fused", num_buffers=3))


def test_kernel_kwargs_autotuned_num_buffers(monkeypatch):
    """autotune=True picks up the tuned ring depth when the problem key is
    given; an explicit num_buffers always wins."""
    from repro.kernels import autotune
    monkeypatch.setattr(
        autotune, "autotune_ec",
        lambda nmodes, rank, variant: autotune.ECConfig(8, 128, 5))
    cfg = api.KernelConfig(use_kernel=True, variant="fused", autotune=True)
    assert cfg.mttkrp_kwargs(nmodes=3, rank=8)["num_buffers"] == 5
    assert cfg.mttkrp_kwargs()["num_buffers"] == 2       # no problem key
    explicit = api.KernelConfig(use_kernel=True, variant="fused",
                                autotune=True, num_buffers=4)
    assert explicit.mttkrp_kwargs(nmodes=3, rank=8)["num_buffers"] == 4


def test_legacy_kwargs_bridge():
    cfg = api.DecomposeConfig.from_legacy_kwargs(
        rank=8, num_devices=2, strategy="equal_nnz", use_kernel=True,
        kernel_variant="blocked", ring=False, tol=0.0, seed=9,
        checkpoint_dir="/tmp/c")
    assert cfg.rank == 8
    assert cfg.partition.strategy == "equal_nnz"
    assert cfg.kernel.resolved_variant() == "blocked"
    assert not cfg.exchange.ring
    assert cfg.runtime == api.RuntimeConfig(
        num_devices=2, checkpoint_dir="/tmp/c", tol=0.0, seed=9)


def test_paper_config_presets():
    from repro.configs.amped_paper import PAPER_DEVICES, RANK, paper_config
    cfg = paper_config("paper")
    assert cfg.rank == RANK and cfg.runtime.num_devices == PAPER_DEVICES
    cfg = paper_config("fused", {"runtime.num_devices": 1})
    assert cfg.kernel.autotune and cfg.runtime.num_devices == 1


def test_legacy_setup_shims():
    """The deprecated *_setup helpers still accept PaperRun field names."""
    from repro.configs.amped_paper import optimized_setup, paper_setup
    with pytest.warns(DeprecationWarning, match="paper_setup"):
        cfg = paper_setup("amazon", num_devices=2, use_kernel=True,
                          kernel_variant="blocked", rank=8)
    assert cfg.runtime.num_devices == 2
    assert cfg.kernel.resolved_variant() == "blocked"
    assert cfg.rank == 8
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="no field"):
            optimized_setup("amazon", bogus_field=1)


# -- plan layer ---------------------------------------------------------------

def _cfg(**over):
    base = {"rank": 8, "runtime.tol": 0.0, "runtime.num_devices": 1}
    return api.preset("paper", {**base, **over})


def test_plan_cache_partitions_once(small_tensor, tmp_path, monkeypatch):
    calls = {"n": 0}
    real = partition_mod.build_plan

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(partition_mod, "build_plan", counting)
    api.reset_cache_stats()
    cfg = _cfg()
    p1 = api.plan(small_tensor, cfg, cache_dir=str(tmp_path))
    p2 = api.plan(small_tensor, cfg, cache_dir=str(tmp_path))
    assert calls["n"] == 1                      # second call never partitioned
    assert api.CACHE_STATS == {"hits": 1, "misses": 1}
    for d in range(p1.nmodes):
        for k in partition_mod.ModePartition.ARRAY_FIELDS:
            np.testing.assert_array_equal(getattr(p1.modes[d], k),
                                          getattr(p2.modes[d], k))


def test_plan_signature_sensitivity(small_tensor, small_tensor_4mode):
    cfg = _cfg()
    s0 = api.plan_signature(small_tensor, cfg, num_devices=1)
    assert s0 == api.plan_signature(small_tensor, cfg, num_devices=1)
    assert s0 != api.plan_signature(small_tensor_4mode, cfg, num_devices=1)
    assert s0 != api.plan_signature(small_tensor, cfg, num_devices=2)
    assert s0 != api.plan_signature(
        small_tensor, cfg.with_overrides({"partition.strategy": "equal_nnz"}),
        num_devices=1)
    assert s0 != api.plan_signature(
        small_tensor, cfg.with_overrides({"partition.tile": 16}),
        num_devices=1)


# -- execute layer ------------------------------------------------------------

def test_solver_sweep_and_result(small_tensor):
    cfg = _cfg()
    solver = api.compile(api.plan(small_tensor, cfg), cfg)
    s1 = solver.sweep()
    assert s1.sweep == 1
    s2 = solver.sweep()
    assert s2.sweep == 2 and len(s2.fits) == 2
    res = solver.result()
    assert isinstance(res, CPResult)
    assert res.sweeps == 2
    assert [f.shape for f in res.factors] == \
        [(s, cfg.rank) for s in small_tensor.shape]


def test_solver_reset(small_tensor):
    cfg = _cfg()
    solver = api.compile(api.plan(small_tensor, cfg), cfg)
    r1 = solver.run(2)
    solver.reset()
    r2 = solver.run(2)
    assert r1.fits == r2.fits  # same seed, same trajectory


def test_shim_matches_staged_api(small_tensor):
    cfg = _cfg(**{"runtime.seed": 3})
    staged = api.compile(api.plan(small_tensor, cfg), cfg).run(3)
    with pytest.warns(DeprecationWarning, match="cp_decompose"):
        legacy = cp_decompose(small_tensor, rank=8, num_devices=1, iters=3,
                              tol=0, seed=3)
    assert staged.fits == legacy.fits  # identical, not merely close
    for f1, f2 in zip(staged.factors, legacy.factors):
        np.testing.assert_array_equal(f1, f2)


def test_solver_checkpoint_restore_roundtrip(small_tensor, tmp_path):
    cfg = _cfg(**{"runtime.checkpoint_dir": str(tmp_path)})
    solver = api.compile(api.plan(small_tensor, cfg), cfg)
    full = solver.run(4)
    solver2 = api.compile(api.plan(small_tensor, cfg), cfg)
    assert solver2.restore()                       # latest = sweep 4
    assert solver2.state.sweep == 4
    resumed = solver2.run(4)                       # nothing left to do
    np.testing.assert_allclose(resumed.fits, full.fits, atol=1e-6)
    for f1, f2 in zip(resumed.factors, full.factors):
        np.testing.assert_allclose(f1, f2, atol=1e-5)


def test_solver_restore_without_ckpt_dir_raises(small_tensor):
    cfg = _cfg()
    solver = api.compile(api.plan(small_tensor, cfg), cfg)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        solver.restore()


# -- CPResult.reconstruct_at --------------------------------------------------

def test_reconstruct_at_matches_dense():
    rng = np.random.default_rng(0)
    a, b, c = (rng.uniform(0.2, 1, (n, 3)).astype(np.float32)
               for n in (6, 5, 4))
    lam = np.asarray([2.0, 0.5, 1.0], np.float64)
    dense = np.einsum("r,ir,jr,kr->ijk", lam, a, b, c)
    res = CPResult(factors=[a, b, c], lam=lam, fits=[], plan=None, sweeps=0)
    ii, jj, kk = np.meshgrid(range(6), range(5), range(4), indexing="ij")
    coords = np.stack([ii.ravel(), jj.ravel(), kk.ravel()], axis=1)
    got = res.reconstruct_at(coords).reshape(6, 5, 4)
    np.testing.assert_allclose(got, dense, rtol=1e-5)
