"""Exchange subsystem (repro.comm) under 4 forced host devices.

The multi-device battery runs in one subprocess (the dry-run isolation
rule: the main test process must keep a single device) and covers the
ISSUE-4 acceptance surface:

* ``ring_all_gather`` vs ``lax.all_gather`` bit-equality,
* the ``overlap`` (chunked double-buffered) variant bit-equal to the
  blocking paths at fp32 — both at the collective level and end-to-end
  through 10 ALS sweeps,
* ``ring_rs`` vs ``psum_scatter`` merge agreement,
* bf16-wire ALS fit within tolerance of the fp32 run over 10 sweeps,
* the non-divisible merge raising a clear ``ValueError`` (satellite
  bugfix) instead of corrupting row ownership.

In-process tests (single device) cover the pure-python surface: variant /
merge resolution precedence, ``ExchangeSpec`` validation, the volume model,
and the chunk-size defaults.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import comm
from repro.api import ExchangeConfig

SCRIPT = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro import comm
import repro.api as api
from repro.core.coo import random_sparse

results = {}
assert jax.device_count() == 4, jax.device_count()
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("group", "sub"))
axes = ("group", "sub")
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(24, 5)).astype(np.float32))

def gather(variant, **kw):
    fn = lambda v: comm.all_gather_axes(v, axes, variant=variant, **kw)
    return np.asarray(jax.jit(shard_map(
        fn, mesh=mesh, in_specs=P(axes), out_specs=P(None)))(x))

ag = gather("allgather")
results["allgather_roundtrip"] = bool((ag == np.asarray(x)).all())
results["ring_bitwise"] = bool((gather("ring") == ag).all())
# overlap: even chunking, uneven tail chunk, and degenerate single chunk
results["overlap_bitwise"] = bool(
    (gather("overlap", chunk_rows=2) == ag).all()
    and (gather("overlap", chunk_rows=4) == ag).all()   # 6 = 4 + 2 tail
    and (gather("overlap", chunk_rows=6) == ag).all())

# --- merge variants over the sub axis (r=2) ------------------------------
y = jnp.asarray(rng.normal(size=(2, 8, 3)).astype(np.float32))

def merge(**kw):
    fn = lambda v: comm.merge_partials(v.reshape(8, 3), "sub", **kw)
    return np.asarray(jax.jit(shard_map(
        fn, mesh=mesh, in_specs=P("group", None, None),
        out_specs=P("group", "sub", None)))(y))

ps = merge(merge="psum_scatter")
results["ring_rs_matches_psum_scatter"] = bool(
    np.allclose(merge(merge="ring_rs"), ps, atol=1e-6))
results["bf16_merge_close"] = bool(
    np.allclose(merge(merge="ring_rs", wire_dtype=jnp.bfloat16), ps,
                atol=5e-2))

# --- non-divisible merge raises at trace time (satellite bugfix) ----------
try:
    merge_bad = lambda v: comm.merge_partials(v.reshape(8, 3)[:7], "sub")
    jax.jit(shard_map(merge_bad, mesh=mesh,
                      in_specs=P("group", None, None),
                      out_specs=P("group", "sub", None)))(y)
    results["nondivisible_raises"] = False
except ValueError as e:
    results["nondivisible_raises"] = "not divisible" in str(e)

# --- end-to-end: 10 ALS sweeps per exchange variant ----------------------
t = random_sparse((40, 30, 20), 1500, seed=7, distribution="zipf")
base = api.paper({"rank": 8, "runtime.tol": 0.0,
                  "partition.replication": 2})
plan = api.plan(t, base)

def run(overrides):
    cfg = base.with_overrides(overrides)
    with api.compile(plan, cfg) as solver:
        return solver.run(10)

r_ag = run({"exchange.variant": "allgather"})
fp32_variants_bitwise = True
for ov in ({"exchange.variant": "ring"},
           {"exchange.variant": "overlap"},
           {"exchange.variant": "overlap", "exchange.chunk_rows": 4},
           {"exchange.variant": "overlap", "exchange.merge": "ring_rs"}):
    r = run(ov)
    fp32_variants_bitwise = fp32_variants_bitwise and all(
        (a == b).all() for a, b in zip(r.factors, r_ag.factors))
results["fp32_variants_bitwise"] = bool(fp32_variants_bitwise)

r_bf16 = run({"exchange.variant": "overlap",
              "exchange.wire_dtype": "bfloat16"})
results["fit_fp32"] = float(r_ag.fits[-1])
results["fit_bf16"] = float(r_bf16.fits[-1])
results["bf16_fit_within_tol"] = bool(
    abs(r_bf16.fits[-1] - r_ag.fits[-1]) < 0.08)

# --- modelled vs measured exchange volume --------------------------------
cfg = base.with_overrides({"exchange.variant": "overlap",
                           "exchange.chunk_rows": 4})
with api.compile(plan, cfg) as solver:
    solver.sweep()
    rep = solver.exchange_report()
results["modelled_bytes"] = rep["modelled"]["sweep_total_bytes"]
results["measured_bytes"] = rep["measured"]["sweep_total_bytes"]
results["volume_model_matches"] = bool(
    rep["modelled"]["sweep_total_bytes"] > 0 and
    abs(rep["measured"]["sweep_total_bytes"] -
        rep["modelled"]["sweep_total_bytes"])
    <= 0.25 * rep["modelled"]["sweep_total_bytes"])
bf16_model = comm.modelled_exchange_bytes(plan, 8, wire_dtype="bfloat16")
results["bf16_half_volume"] = bool(
    bf16_model["sweep_total_bytes"] * 2
    == rep["modelled"]["sweep_total_bytes"])

print("RESULTS_JSON:" + json.dumps(results))
"""


@pytest.mark.slow
def test_exchange_battery_4dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = next(l for l in proc.stdout.splitlines()
                if l.startswith("RESULTS_JSON:"))
    results = json.loads(line[len("RESULTS_JSON:"):])
    assert results["allgather_roundtrip"]
    assert results["ring_bitwise"]
    assert results["overlap_bitwise"]
    assert results["ring_rs_matches_psum_scatter"]
    assert results["bf16_merge_close"]
    assert results["nondivisible_raises"]
    assert results["fp32_variants_bitwise"]
    assert results["bf16_fit_within_tol"], (
        results["fit_fp32"], results["fit_bf16"])
    assert results["volume_model_matches"], results
    assert results["bf16_half_volume"]


# --- in-process (single device): resolution, validation, volume model -----

def test_variant_resolution_precedence(monkeypatch):
    monkeypatch.delenv(comm.ENV_VARIANT, raising=False)
    # legacy ring flag maps onto the registry
    assert comm.resolve_variant(None, True) == "ring"
    assert comm.resolve_variant(None, False) == "allgather"
    assert comm.resolve_variant(None, None) == comm.DEFAULT_VARIANT
    # env beats the legacy flag, explicit argument beats env
    monkeypatch.setenv(comm.ENV_VARIANT, "overlap")
    assert comm.resolve_variant(None, True) == "overlap"
    assert comm.resolve_variant("ring", True) == "ring"
    with pytest.raises(ValueError, match="unknown exchange variant"):
        comm.resolve_variant("nope")


def test_merge_resolution(monkeypatch):
    monkeypatch.delenv(comm.ENV_MERGE, raising=False)
    assert comm.resolve_merge(None) == "psum_scatter"
    monkeypatch.setenv(comm.ENV_MERGE, "ring_rs")
    assert comm.resolve_merge(None) == "ring_rs"
    with pytest.raises(ValueError, match="unknown exchange merge"):
        comm.resolve_merge("nope")


def test_exchange_config_validation():
    assert ExchangeConfig().resolved_variant() == "ring"
    assert ExchangeConfig(ring=False).resolved_variant() == "allgather"
    assert ExchangeConfig(variant="overlap").resolved_variant() == "overlap"
    with pytest.raises(ValueError, match="exchange.variant"):
        ExchangeConfig(variant="bogus")
    with pytest.raises(ValueError, match="exchange.merge"):
        ExchangeConfig(merge="bogus")
    with pytest.raises(ValueError, match="wire_dtype"):
        ExchangeConfig(wire_dtype="float16")
    with pytest.raises(ValueError, match="chunk_rows"):
        ExchangeConfig(chunk_rows=0)


def test_exchange_spec_resolution(monkeypatch):
    monkeypatch.delenv(comm.ENV_VARIANT, raising=False)
    monkeypatch.delenv(comm.ENV_MERGE, raising=False)
    spec = comm.resolve_exchange_spec(ExchangeConfig(
        variant="overlap", merge="ring_rs", chunk_rows=16,
        wire_dtype="bfloat16"))
    assert (spec.variant, spec.merge, spec.chunk_rows) == \
        ("overlap", "ring_rs", 16)
    assert spec.reduced_wire and str(spec.wire) == "bfloat16"
    # full-precision spec emits no casts at all
    assert comm.resolve_exchange_spec(ExchangeConfig()).wire is None
    with pytest.raises(ValueError):
        comm.ExchangeSpec(variant="bogus")


def test_bf16_wire_merge_normalization(monkeypatch):
    """A bf16 wire always runs the ring_rs merge: the DEFAULT merge is
    normalized so the spec (and every report built from it) names the
    schedule that actually executes, while an EXPLICIT psum_scatter
    request is a contradiction and raises."""
    monkeypatch.delenv(comm.ENV_MERGE, raising=False)
    spec = comm.resolve_exchange_spec(
        ExchangeConfig(wire_dtype="bfloat16"))
    assert spec.merge == "ring_rs"
    with pytest.raises(ValueError, match="psum_scatter"):
        comm.resolve_exchange_spec(ExchangeConfig(
            wire_dtype="bfloat16", merge="psum_scatter"))
    monkeypatch.setenv(comm.ENV_MERGE, "psum_scatter")
    with pytest.raises(ValueError, match="psum_scatter"):
        comm.resolve_exchange_spec(ExchangeConfig(wire_dtype="bfloat16"))
    with pytest.raises(ValueError, match="psum_scatter"):
        comm.ExchangeSpec(wire_dtype="bfloat16", merge="psum_scatter")


def test_core_exchange_shim_keeps_historical_default(monkeypatch):
    """repro.core.exchange.all_gather_axes pre-dates the variant registry:
    its ring flag must keep defaulting to False (native all_gather) and
    must NOT be swayed by AMPED_EXCHANGE_VARIANT."""
    import inspect

    from repro.core import exchange as core_exchange

    sig = inspect.signature(core_exchange.all_gather_axes)
    assert sig.parameters["ring"].default is False
    # behavioral: under env=ring, the shim still lowers the default path
    # to a plain all-gather (no collective-permute ring)
    monkeypatch.setenv(comm.ENV_VARIANT, "ring")
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("group", "sub"))
    fn = shard_map(
        lambda v: core_exchange.all_gather_axes(v, ("group", "sub")),
        mesh=mesh, in_specs=P(("group", "sub")), out_specs=P(None))
    txt = jax.jit(fn).lower(jnp.ones((4, 3))).as_text()
    assert "collective_permute" not in txt and "ppermute" not in txt


def test_volume_model(small_tensor):
    from repro.core.partition import build_plan
    plan = build_plan(small_tensor, 1)
    rank = 8
    # single device: no exchange at all
    assert comm.modelled_exchange_bytes(plan, rank)["sweep_total_bytes"] == 0
    # the m-device ring model: (m-1) * rows/r * R * 4 per device and mode
    plan4 = build_plan(small_tensor, 4, replication=2)
    model = comm.modelled_exchange_bytes(plan4, rank)
    for part, row in zip(plan4.modes, model["per_mode"]):
        gather_rows = part.rows_max // part.r
        assert row["gather_bytes"] == 3 * gather_rows * rank * 4
        assert row["merge_bytes"] == (part.rows_max // 2) * rank * 4
    half = comm.modelled_exchange_bytes(plan4, rank, wire_dtype="bfloat16")
    assert half["sweep_total_bytes"] * 2 == model["sweep_total_bytes"]


def test_default_chunk_rows():
    assert comm.default_chunk_rows(24) == 12
    assert comm.default_chunk_rows(1) == 1
    assert comm.default_chunk_rows(3) == 2
