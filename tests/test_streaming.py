"""Epoch-streaming execution: super-shard splits from manifest stats (zero
chunk reads), window materialization bit-identical to whole-shard
materialization across every partition strategy, budget validation errors,
mid-chunk window boundaries, the streaming CPSolver path producing bitwise
fp32-identical fits under a budget 4x+ smaller than the tensor's shard
bytes, the on-disk window spill cache (sweep-invariant preprocessing
replayed bitwise), and the scheduler's streaming-budget awareness (H2D
cost term and the migration clamp)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import repro.api as api
from repro.core.coo import random_sparse
from repro.store import (TensorStore, budget_slot_cap, build_plan_from_store,
                         resident_shard_nbytes, split_mode_super_shards,
                         write_store_from_coo)


@pytest.fixture(scope="module")
def zipf_tensor():
    return random_sparse((200, 60, 30), 5000, seed=3, distribution="zipf",
                         dedup=False)


@pytest.fixture(scope="module")
def zipf_store(zipf_tensor, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("stream_store") / "z.store")
    write_store_from_coo(zipf_tensor, path, chunk_nnz=512)
    return TensorStore(path)


def _feasible_budget(parts, frac_of_total=0.33, buffers=2):
    """A budget that is a fraction of the modes' total resident shard bytes
    but never below any mode's densest-tile / chunk-staging minimum."""
    nmodes = parts[0].nmodes
    total = sum(resident_shard_nbytes(p, nmodes) for p in parts)
    floors = []
    for p in parts:
        per_slot = 4 * nmodes + 8 + 4 / p.block_p
        dense = int(p._dev_tc_pad.max()) if p._dev_tc_pad.size else 0
        floors.append(buffers * int(max(dense, p.block_p) * per_slot
                                    + p.layout.n_tiles * 4 + 1))
        floors.append(p.store.chunk_nnz * (8 * nmodes + 4))
    return max(int(total * frac_of_total), *floors)


# -- super-shard split (plan-from-stats) -------------------------------------

def test_split_reads_no_chunks_and_covers_tiles(zipf_store):
    plan = build_plan_from_store(zipf_store, 4)
    zipf_store.reset_access_stats()
    budget = _feasible_budget(plan.modes)
    for part in plan.modes:
        sp = split_mode_super_shards(part, budget)
        assert zipf_store.access_stats["chunk_reads"] == 0
        assert sp.buffers * sp.shard_bytes <= budget
        assert sp.resident_bound_bytes() == sp.buffers * sp.shard_bytes
        for dev in range(part.num_devices):
            wins = [w for w in sp.windows[dev] if w != (0, 0)]
            # non-empty windows tile [0, n_tiles) contiguously, in order
            assert wins[0][0] == 0 and wins[-1][1] == sp.n_tiles
            for (a0, a1), (b0, b1) in zip(wins, wins[1:]):
                assert a1 == b0 and a0 < a1
            # (0, 0) padding only at the tail
            assert sp.windows[dev][:len(wins)] == tuple(wins)


@pytest.mark.parametrize("m,strategy,repl", [
    (4, "amped_cdf", 1),
    (4, "amped_cdf", 2),
    (4, "amped_lpt", 1),
    (4, "equal_nnz", None),
    (4, "uniform_index", None),
])
def test_window_concat_bit_identity(zipf_store, m, strategy, repl):
    """Concatenating every super-shard window's real slots reproduces the
    whole-shard device arrays bit-for-bit — every strategy, including
    replicated and scattered (non-contiguous) ownership."""
    plan = build_plan_from_store(zipf_store, m, strategy=strategy,
                                 replication=repl)
    budget = _feasible_budget(plan.modes)
    split = False
    for part in plan.modes:
        sp = split_mode_super_shards(part, budget)
        split = split or sp.num_shards > 1
        for dev in range(part.num_devices):
            full_i, full_v, full_r = part.device_arrays(dev)
            pieces_i, pieces_v, pieces_r = [], [], []
            for (t0, t1) in sp.windows[dev]:
                wi, wv, wr, b2t, vis = part.super_shard_arrays(
                    dev, t0, t1, nnz_cap=sp.nnz_cap, nblocks=sp.nblocks)
                need = int(part._dev_tc_pad[dev, t0:t1].sum())
                pieces_i.append(wi[:need])
                pieces_v.append(wv[:need])
                pieces_r.append(wr[:need])
                # a real window's tile mask marks only tiles it covers (an
                # empty pad window keeps the resident pad convention:
                # block_to_tile all 0 => visited[0] = 1)
                if t1 > t0:
                    assert set(np.flatnonzero(vis)) <= set(range(t0, t1))
            tot = sum(p.shape[0] for p in pieces_i)
            np.testing.assert_array_equal(np.concatenate(pieces_i),
                                          full_i[:tot])
            np.testing.assert_array_equal(np.concatenate(pieces_v),
                                          full_v[:tot])
            np.testing.assert_array_equal(np.concatenate(pieces_r),
                                          full_r[:tot])
            assert (full_v[tot:] == 0).all()  # remainder is pure padding
    assert split  # the budget actually forced multi-shard streaming


def test_budget_below_chunk_staging_raises(zipf_store):
    part = build_plan_from_store(zipf_store, 2).modes[0]
    chunk_bytes = zipf_store.chunk_nnz * (8 * part.nmodes + 4)
    with pytest.raises(ValueError, match="staging footprint"):
        split_mode_super_shards(part, chunk_bytes - 1)


def test_budget_below_densest_tile_raises(zipf_tensor, tmp_path):
    # tiny chunks so the chunk-staging floor sits below the tile floor
    path = str(tmp_path / "tiny.store")
    write_store_from_coo(zipf_tensor, path, chunk_nnz=64)
    part = build_plan_from_store(TensorStore(path), 1).modes[0]
    chunk_bytes = 64 * (8 * part.nmodes + 4)
    with pytest.raises(ValueError, match="densest row tile"):
        split_mode_super_shards(part, chunk_bytes + 256)


def test_window_boundary_mid_chunk(zipf_tensor, tmp_path):
    """On a mode-sorted store a chunk's row range is tight; a super-shard
    boundary falling inside it forces that chunk to be read by two
    consecutive windows — and the windows stay bit-identical to the
    whole-shard path."""
    t = zipf_tensor.sorted_by_mode(0)
    path = str(tmp_path / "sorted.store")
    write_store_from_coo(t, path, chunk_nnz=500)
    st = TensorStore(path)
    plan = build_plan_from_store(st, 1)
    part = plan.modes[0]
    sp = split_mode_super_shards(part, _feasible_budget([part]))
    assert sp.num_shards >= 2
    st.reset_access_stats()
    pieces = []
    for (t0, t1) in sp.windows[0]:
        wi, _, _, _, _ = part.super_shard_arrays(
            0, t0, t1, nnz_cap=sp.nnz_cap, nblocks=sp.nblocks)
        pieces.append(wi[:int(part._dev_tc_pad[0, t0:t1].sum())])
    # the single device owns every row: each chunk overlaps some window, and
    # at least one boundary chunk is read by two windows
    assert st.access_stats["chunk_reads"] > st.num_chunks
    full_i, _, _ = part.device_arrays(0)
    tot = sum(p.shape[0] for p in pieces)
    np.testing.assert_array_equal(np.concatenate(pieces), full_i[:tot])


# -- solver integration ------------------------------------------------------

def test_streaming_requires_lazy_plan(zipf_tensor):
    cfg = api.paper({"rank": 4, "runtime.streaming": True,
                     "runtime.memory_budget": 1 << 20})
    plan = api.plan(zipf_tensor, cfg)
    with pytest.raises(ValueError, match="out-of-core plan"):
        api.compile(plan, cfg)


def test_streaming_requires_budget(zipf_store):
    cfg = api.paper({"rank": 4, "runtime.streaming": True})
    plan = api.plan(zipf_store, cfg)
    with pytest.raises(ValueError, match="memory_budget"):
        api.compile(plan, cfg)


def test_streaming_e2e_bitwise_and_budget_bounded(tmp_path):
    """Acceptance: a store whose total shard bytes are >= 4x the device
    budget decomposes in streaming mode with fits bitwise fp32-equal to the
    resident path, factors bitwise equal, and peak streamed device bytes
    never above the budget. Long modes and a flat index distribution, so
    tile-boundary windows can cut well below the whole-shard footprint
    (skewed tensors are covered by the zipf bit-identity tests above; a
    zipf head tile alone can pin half a shard, bounding how small the
    budget may go)."""
    t = random_sparse((400, 300, 200), 12000, seed=7, dedup=False)
    path = str(tmp_path / "wide.store")
    write_store_from_coo(t, path, chunk_nnz=1024)
    wide_store = TensorStore(path)
    cfg = api.paper({"rank": 8, "runtime.tol": 0.0})
    plan = api.plan(wide_store, cfg)
    total = sum(resident_shard_nbytes(p, plan.nmodes) for p in plan.modes)
    budget = _feasible_budget(plan.modes, frac_of_total=0.2)
    assert total >= 4 * budget, (total, budget)

    with api.compile(plan, cfg) as s1:
        r1 = s1.run(3)
    scfg = cfg.with_overrides({"runtime.streaming": True,
                               "runtime.memory_budget": budget})
    with api.compile(api.plan(wide_store, scfg), scfg) as s2:
        assert max(sp.num_shards for sp in s2.stream_plans) >= 2
        with pytest.raises(RuntimeError, match="streaming mode"):
            s2.dev_arrays
        r2 = s2.run(3)
        report = s2.overlap_report()

    assert r2.fits == r1.fits  # bitwise fp32-identical fit trajectory
    for a, b in zip(r1.factors, r2.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert report["enabled"]
    assert report["peak_resident_bytes"] <= budget
    assert report["builds"] > 0 and report["bytes_streamed"] > 0
    assert len(report["per_sweep"]) == 3
    # sweeps 2-3 replayed spilled windows, so the bitwise equality above
    # covers the spill path too; steady-state overlap excludes sweep 1
    assert report["spill_saves"] > 0 and report["spill_hits"] > 0
    assert report["overlap_fraction_steady"] is not None
    # exchange measurement is explicitly skipped, not wrong, in streaming
    assert "measured_skipped" in s2.exchange_report()


# -- window spill cache -------------------------------------------------------

def test_window_spill_reuse_and_cleanup(zipf_store):
    """A re-requested window must come back from the spill (no second
    chunk scan) bitwise identical, the streamer must surface the spill
    counters, and closing the streamer must remove an owned temp dir."""
    from repro.core import mttkrp as dm
    from repro.sparse.stream import SuperShardStreamer, WindowSpill

    cfg = api.paper({"rank": 4, "runtime.tol": 0.0})
    plan = api.plan(zipf_store, cfg)
    budget = _feasible_budget(plan.modes)
    sps = [split_mode_super_shards(p, budget, buffers=2)
           for p in plan.modes]
    spill = WindowSpill()
    root = spill.root
    # buffers=1: no prefetch, so the eviction/reload order is deterministic
    s = SuperShardStreamer(plan, dm.cp_mesh(1, 1), sps, buffers=1,
                           spill=spill)
    first = s.get(0, 0)
    vals = np.asarray(first.values).copy()
    inds = np.asarray(first.indices).copy()
    assert spill.saves >= 1 and spill.hits == 0
    s.get(1, 0)           # evicts (0, 0) — single-buffer residency
    again = s.get(0, 0)   # rebuild must go through the spill
    assert spill.hits >= 1
    np.testing.assert_array_equal(np.asarray(again.values), vals)
    np.testing.assert_array_equal(np.asarray(again.indices), inds)
    assert s.stats_snapshot()["spill_hits"] == spill.hits
    assert os.listdir(root)
    s.close()             # streamer owns the spill; owned tempdir removed
    assert not os.path.exists(root)


def test_solver_spill_config(zipf_store, tmp_path):
    """runtime.stream_spill_dir persists windows past close (reusable
    preprocessing); runtime.stream_spill=False runs spill-less."""
    plan0 = api.plan(zipf_store, api.paper({"rank": 4}))
    budget = _feasible_budget(plan0.modes)
    base = api.paper({"rank": 4, "runtime.tol": 0.0,
                      "runtime.streaming": True,
                      "runtime.memory_budget": budget})
    spill_dir = str(tmp_path / "spill")
    cfg = base.with_overrides({"runtime.stream_spill_dir": spill_dir})
    with api.compile(api.plan(zipf_store, cfg), cfg) as solver:
        solver.run(2)
        rep = solver.overlap_report()
    assert rep["spill_saves"] > 0 and rep["spill_hits"] > 0
    assert rep["overlap_fraction_steady"] is not None
    assert os.listdir(spill_dir)  # explicit dir survives close

    off = base.with_overrides({"runtime.stream_spill": False})
    with api.compile(api.plan(zipf_store, off), off) as solver:
        assert solver.streamer.spill is None
        solver.run(1)
        rep = solver.overlap_report()
    assert rep["spill_saves"] == 0 and rep["spill_hits"] == 0


# -- scheduler streaming-budget awareness ------------------------------------

def test_device_stream_bytes_and_h2d_term(zipf_tensor):
    from repro.core.partition import build_plan
    from repro.schedule import cost

    part = build_plan(zipf_tensor, 4).modes[0]
    sb = cost.device_stream_bytes(part, 3)
    slots = np.asarray(part.blocks_true) * part.block_p
    np.testing.assert_array_equal(
        sb, slots * 20 + np.asarray(part.blocks_true) * 4
        + (part.rows_max // part.tile) * 4)
    base = cost.predict_times(part, cost.DEFAULT_COEFFS, nmodes=3)
    np.testing.assert_array_equal(base, cost.predict_times(part))  # off by default
    c = cost.CostCoefficients(sec_per_h2d_byte=1e-9)
    np.testing.assert_allclose(
        cost.predict_times(part, c, nmodes=3) - cost.predict_times(part, c),
        1e-9 * sb)
    summary = cost.mode_cost_summary(part, 8, c, nmodes=3)
    assert summary["stream_bytes_per_device"] == [int(x) for x in sb]


def test_ewma_update_preserves_h2d_coeff():
    from repro.schedule import cost

    m = cost.EwmaCostModel(coeffs=cost.CostCoefficients(
        sec_per_h2d_byte=2e-9))
    feats = np.array([[100.0, 128.0, 1.0], [200.0, 256.0, 1.0],
                      [400.0, 512.0, 1.0]])
    times = feats @ np.array([1e-6, 1e-7, 1e-4])
    m.update(feats, times)
    assert m.coeffs.sec_per_h2d_byte == 2e-9
    m.update(feats, times * 2)
    assert m.coeffs.sec_per_h2d_byte == 2e-9


class _GroupStub:
    """Just what plan_group_migrations touches."""

    mode = 0
    r = 3
    block_p = 4
    n_groups = 1

    def __init__(self, nnz):
        self.nnz_true = np.asarray(nnz, np.int64)


def test_migration_clamp_respects_member_cap():
    from repro.schedule.rebalance import plan_group_migrations

    part = _GroupStub([300, 60, 120])
    times = np.array([3.0, 1.0, 1.0])
    unclamped = plan_group_migrations(part, times, migration_budget=1.0)
    assert unclamped and max(unclamped[0].nnz_target) > 160
    clamped = plan_group_migrations(part, times, migration_budget=1.0,
                                    max_member_nnz=160)
    assert clamped
    tgt = clamped[0].nnz_target
    assert max(tgt) <= 160
    assert sum(tgt) == 480                       # zero-sum preserved
    assert all(x % 4 == 0 for x in tgt)          # block granular


def test_migration_skipped_when_budget_has_no_headroom():
    from repro.schedule.rebalance import plan_group_migrations

    part = _GroupStub([400, 80])
    part.r = 2
    times = np.array([4.0, 1.0])
    assert plan_group_migrations(part, times, migration_budget=1.0)
    # a cap below what any member could absorb kills the whole re-split
    assert plan_group_migrations(part, times, migration_budget=1.0,
                                 max_member_nnz=100) == []


def test_budget_slot_cap_inverts_shard_bytes():
    from repro.store import stream_shard_nbytes

    cap = budget_slot_cap(1 << 20, nmodes=3, n_tiles=16, block_p=128,
                          buffers=2)
    assert cap > 0 and cap % 128 == 0
    assert 2 * stream_shard_nbytes(cap, cap // 128, 16, 3) <= 1 << 20
    bigger = cap + 128
    assert 2 * stream_shard_nbytes(bigger, bigger // 128, 16, 3) > 1 << 20
    assert budget_slot_cap(64, nmodes=3, n_tiles=16, block_p=128) == 0


# -- 4-forced-device battery -------------------------------------------------

STREAM_MULTIDEV_SCRIPT = r"""
import json
import numpy as np
import jax
assert jax.device_count() == 4, jax.device_count()

import repro.api as api
from repro.core.coo import random_sparse
from repro.store import TensorStore, write_store_from_coo
from repro.store.plan import resident_shard_nbytes

t = random_sparse((200, 60, 30), 5000, seed=3, distribution="zipf",
                  dedup=False)
write_store_from_coo(t, "{store}", chunk_nnz=512)
st = TensorStore("{store}")

def feasible_budget(parts, frac):
    nmodes = parts[0].nmodes
    total = sum(resident_shard_nbytes(p, nmodes) for p in parts)
    floors = []
    for p in parts:
        per_slot = 4 * nmodes + 8 + 4 / p.block_p
        dense = int(p._dev_tc_pad.max()) if p._dev_tc_pad.size else 0
        floors.append(2 * int(max(dense, p.block_p) * per_slot
                              + p.layout.n_tiles * 4 + 1))
        floors.append(p.store.chunk_nnz * (8 * nmodes + 4))
    return max(int(total * frac), *floors)

out = {{}}
for strategy, repl in [("amped_cdf", 2), ("amped_lpt", 1),
                       ("equal_nnz", None), ("uniform_index", None)]:
    over = {{"rank": 8, "runtime.tol": 0.0, "partition.strategy": strategy}}
    if repl is not None:
        over["partition.replication"] = repl
    cfg = api.paper(over)
    plan = api.plan(st, cfg)
    budget = feasible_budget(plan.modes, 0.3)
    with api.compile(plan, cfg) as s1:
        r1 = s1.run(2)
    scfg = cfg.with_overrides({{"runtime.streaming": True,
                               "runtime.memory_budget": budget}})
    with api.compile(api.plan(st, scfg), scfg) as s2:
        r2 = s2.run(2)
        rep = s2.overlap_report()
    out[strategy] = {{
        "fits_equal": r1.fits == r2.fits,
        "factors_equal": all((np.asarray(a) == np.asarray(b)).all()
                             for a, b in zip(r1.factors, r2.factors)),
        "multi_shard": max(sp.num_shards for sp in s2.stream_plans) > 1,
        "peak_ok": rep["peak_resident_bytes"] <= budget,
    }}
print("RESULT_JSON:" + json.dumps(out))
"""


@pytest.mark.slow
def test_multidevice_streaming_battery(tmp_path):
    """4 forced host devices x 4 partition strategies (incl. replication):
    streaming fits and factors bitwise equal to resident, with multi-shard
    splits and the per-device peak inside the budget."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = STREAM_MULTIDEV_SCRIPT.format(store=str(tmp_path / "s.store"))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = next(l for l in proc.stdout.splitlines()
                if l.startswith("RESULT_JSON:"))
    out = json.loads(line[len("RESULT_JSON:"):])
    assert set(out) == {"amped_cdf", "amped_lpt", "equal_nnz",
                        "uniform_index"}
    for strategy, row in out.items():
        assert row["fits_equal"], (strategy, row)
        assert row["factors_equal"], (strategy, row)
        assert row["multi_shard"], (strategy, row)
        assert row["peak_ok"], (strategy, row)
