"""End-to-end dynamic rebalancing on 4 forced host devices (subprocess, per
the dry-run isolation rule): on a hot-index skewed tensor,

  * ``rebalance="measure"`` leaves factors BITWISE identical to
    ``rebalance="off"`` (telemetry only — the probes never touch state),
  * ``rebalance="on"`` reduces the max/mean per-device EC-time ratio across
    sweeps versus the ``measure`` baseline measured in the SAME process
    (same probe-overhead regime, so the comparison is noise-robust), while
    converging to the same fit within tolerance, via shape-preserving
    block-granular migrations (epoch bumped, zero recompilation of the
    sweep updates).
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import json
import numpy as np
import jax

assert jax.device_count() == 4, jax.device_count()

import repro.api as api
from repro.core.coo import SparseTensor

# Hot-index mode 0: 30% of nonzeros on 3 indices, the rest scattered over
# 65536 — the scattered members' equal-nnz chunks land ~1 entry per output
# tile, so per-tile padding makes them execute ~18x the kernel slots of the
# hot member (blocks_true ~[154, 2486, 2853, 2865] at equal nnz_true): the
# misprediction the rebalancer corrects.
NNZ = 80000
rng = np.random.default_rng(0)
hot = NNZ * 3 // 10
i0 = np.concatenate([rng.integers(0, 3, hot),
                     rng.integers(3, 65536, NNZ - hot)])
t = SparseTensor(
    np.stack([i0, rng.integers(0, 256, NNZ), rng.integers(0, 256, NNZ)], 1
             ).astype(np.int32),
    rng.standard_normal(NNZ).astype(np.float32), (65536, 256, 256)
).deduplicated()

base = api.paper({"rank": 16, "runtime.tol": 0.0,
                  "partition.strategy": "equal_nnz"})
results = {"nnz": t.nnz}

def run(rebalance):
    cfg = base.with_overrides({
        "schedule.rebalance": rebalance, "schedule.cadence": 1,
        "schedule.imbalance_threshold": 1.1,
        "schedule.migration_budget": 0.4,
        "schedule.probe_repeats": 2})  # best-of-2 kills transient spikes
    solver = api.compile(api.plan(t, cfg), cfg)
    res = solver.run(5)
    traj = [max(e["imbalance"].values()) for e in solver.schedule_events]
    return solver, res, traj

s_plain = api.compile(api.plan(t, base), base)
r_plain = s_plain.run(5)
s_meas, r_meas, traj_off = run("measure")
s_on, r_on, traj_on = run("on")

# measure-mode must be bitwise identical to scheduler-off
results["measure_bitwise"] = bool(all(
    np.array_equal(a, b) for a, b in zip(r_meas.factors, r_plain.factors)))
results["measure_fits_equal"] = bool(r_meas.fits == r_plain.fits)
results["plan_epoch_off"] = int(s_meas.plan.rebalance_epoch)

results.update({
    "traj_off": traj_off,
    "traj_on": traj_on,
    "moved_nnz": int(sum(e["moved_nnz"] for e in s_on.schedule_events)),
    "epoch": int(s_on.plan.rebalance_epoch),
    "fit_off": float(r_plain.fits[-1]),
    "fit_on": float(r_on.fits[-1]),
    "mode0_nnz_after": [int(x) for x in s_on.plan.modes[0].nnz_true],
    "mode0_blocks_after": [int(x) for x in s_on.plan.modes[0].blocks_true],
    "report": s_on.imbalance_report()["per_mode"][0],
})
print("RESULTS_JSON:" + json.dumps(results))
"""


@pytest.mark.slow
def test_rebalance_reduces_imbalance_same_fit():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = next(l for l in proc.stdout.splitlines()
                if l.startswith("RESULTS_JSON:"))
    r = json.loads(line[len("RESULTS_JSON:"):])

    # telemetry alone never perturbs the solve
    assert r["measure_bitwise"], "measure-mode factors must be bitwise equal"
    assert r["measure_fits_equal"]
    assert r["plan_epoch_off"] == 0

    # migrations happened and took effect incrementally
    assert r["moved_nnz"] > 0
    assert r["epoch"] >= 1
    # nnz moved away from the originally-equal split toward the hot member
    nnz_after = r["mode0_nnz_after"]
    assert max(nnz_after) - min(nnz_after) > 0

    # the static plan stays imbalanced without migrations; with them the
    # max/mean per-device EC-time ratio measurably drops (>=10% vs the
    # same-process baseline; min over the last two points so one noisy
    # probe cannot flip the verdict)
    off_tail = sum(r["traj_off"][-2:]) / 2
    on_final = min(r["traj_on"][-2:])
    assert off_tail > 1.15, (r["traj_off"], r["traj_on"])
    assert on_final < 0.9 * off_tail, (r["traj_off"], r["traj_on"])

    # same decomposition: fit agrees within tolerance
    assert abs(r["fit_on"] - r["fit_off"]) < 5e-3, (r["fit_on"], r["fit_off"])
