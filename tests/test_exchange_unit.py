"""Unit tests for exchange collectives + roofline analytics + profile-level
partitioner behaviour (single device; multi-device in test_multidevice)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import exchange
from repro.core.partition import auto_replication
from repro.launch import roofline as rf
from repro.sparse.io import DATASET_PROFILES


def _mesh1():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("group", "sub"))


def test_ring_all_gather_single_device_identity():
    mesh = _mesh1()
    x = jnp.arange(12.0).reshape(4, 3)

    def f(x):
        return exchange.ring_all_gather(x, ("group", "sub"))

    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P(("group", "sub")),
                                out_specs=P(None)))(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_merge_partials_identity_r1():
    mesh = _mesh1()
    x = jnp.ones((8, 4))

    def f(x):
        return exchange.merge_partials(x, "sub")  # r=1 → identity

    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P(None, None),
                                out_specs=P(None, None)))(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_auto_replication_on_paper_profiles():
    """Patents mode-0 (46 indices) on 256 devices NEEDS the beyond-paper
    hierarchical replication — the paper's scheme (r=1) cannot occupy the
    mesh; uniform big modes keep the paper scheme."""
    m = 256
    patents = DATASET_PROFILES["patents"]
    hist = np.full(patents.shape[0], patents.nnz // patents.shape[0])
    r = auto_replication(hist, m)
    assert m // r <= patents.shape[0] and r >= 8
    amazon = DATASET_PROFILES["amazon"]
    hist = np.full(10_000, amazon.nnz // amazon.shape[0])  # flat sample
    assert auto_replication(hist, m) == 1
    # single hot index (Twitch streamers effect): r must split it
    hist = np.ones(100_000, np.int64)
    hist[0] = 10_000_000
    assert auto_replication(hist, m) > 1


def test_traffic_factors():
    hlo = """
  %x1 = f32[1024]{0} all-reduce(f32[1024] %a)
  %x2 = f32[1024]{0} reduce-scatter(f32[4096] %b), dimensions={0}
"""
    coll = rf.collective_bytes(hlo)
    assert coll["all-reduce"] == 1024 * 4 * 2.0     # ring: 2× bytes
    assert coll["reduce-scatter"] == 1024 * 4 * 1.0


def test_analytic_memory_decode_dominated_by_kv():
    meta = dict(chips=256, params=9_000_000_000, kind="decode",
                seq=32768, batch=128, d_model=4096, n_layers=32,
                kv_bytes=1.0e12, remat=False)
    b = rf.analytic_memory_bytes(meta)
    # params 18 GB + kv 1 TB over 256 chips ≈ 4 GB/chip
    assert 3.5e9 < b < 4.5e9


def test_analytic_memory_train_remat_reduces():
    meta = dict(chips=256, params=9e9, kind="train", seq=4096, batch=256,
                d_model=4096, n_layers=32, kv_bytes=0.0)
    full = rf.analytic_memory_bytes({**meta, "remat": False})
    re = rf.analytic_memory_bytes({**meta, "remat": True})
    assert re < full


def test_parse_hlo_nested_loops():
    """Nested while loops multiply trip counts."""
    import jax.numpy as jnp

    def f(xs, w):
        def outer(c, x):
            def inner(c2, y):
                return c2 + y @ w, None
            c2, _ = jax.lax.scan(inner, c, x)
            return c2, None
        out, _ = jax.lax.scan(outer, jnp.zeros((2, 4)), xs)
        return out

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((3, 5, 2, 6), jnp.float32),
        jax.ShapeDtypeStruct((6, 4), jnp.float32)).compile()
    r = rf.parse_hlo(compiled.as_text())
    # 2·2·4·6 = 96 flops per inner step × 5 inner × 3 outer = 1440
    assert r["dot_flops"] == 3 * 5 * 96.0, r
