import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.coo import SparseTensor, from_dense, random_sparse, to_dense
from repro.sparse.io import (DATASET_PROFILES, make_profile_tensor, read_tns,
                             write_tns)


def test_basic_container():
    t = random_sparse((10, 8, 6), 50, seed=0)
    assert t.nmodes == 3
    assert t.indices.dtype == np.int32
    assert t.values.dtype == np.float32
    assert (t.indices >= 0).all()
    assert (t.indices < np.array(t.shape)).all()


def test_validation():
    with pytest.raises(ValueError):
        SparseTensor(np.zeros((3, 2), np.int32), np.zeros(2, np.float32), (5, 5))
    with pytest.raises(ValueError):
        SparseTensor(np.array([[9, 0]], np.int32), np.ones(1, np.float32), (5, 5))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_dense_roundtrip(seed):
    rng = np.random.default_rng(seed)
    dense = (rng.random((6, 5, 4)) < 0.3) * rng.normal(size=(6, 5, 4))
    dense = dense.astype(np.float32)
    t = from_dense(dense)
    np.testing.assert_allclose(to_dense(t), dense, rtol=1e-6)


def test_dedup_accumulates():
    ind = np.array([[1, 2], [1, 2], [0, 0]], np.int32)
    val = np.array([1.0, 2.5, -1.0], np.float32)
    t = SparseTensor(ind, val, (3, 3)).deduplicated()
    assert t.nnz == 2
    d = to_dense(t)
    assert d[1, 2] == pytest.approx(3.5)
    assert d[0, 0] == pytest.approx(-1.0)


def test_mode_histogram_counts():
    t = random_sparse((12, 9, 7), 200, seed=3)
    for m in range(3):
        h = t.mode_histogram(m)
        assert h.sum() == t.nnz
        assert h.shape == (t.shape[m],)


def test_sorted_by_mode():
    t = random_sparse((12, 9, 7), 200, seed=4)
    s = t.sorted_by_mode(1)
    assert (np.diff(s.indices[:, 1]) >= 0).all()
    # same multiset of nonzeros
    assert sorted(map(tuple, np.c_[t.indices, t.values].tolist())) == \
        sorted(map(tuple, np.c_[s.indices, s.values].tolist()))


def test_tns_roundtrip(tmp_path):
    t = random_sparse((9, 8, 7, 6, 5), 100, seed=5)
    p = str(tmp_path / "x.tns")
    write_tns(p, t)
    t2 = read_tns(p)
    d1, d2 = to_dense(t), to_dense(t2)
    # shapes may shrink to max index; embed into the larger one
    assert d2.shape <= d1.shape
    np.testing.assert_allclose(d1[tuple(slice(0, s) for s in d2.shape)], d2,
                               atol=1e-5)


def test_tns_roundtrip_multichunk(tmp_path):
    """The chunked reader: a file spanning many read batches, with comment
    and blank lines interleaved, round-trips exactly (same nonzero multiset,
    1-based convention preserved)."""
    t = random_sparse((31, 17, 13), 400, seed=6)
    p = str(tmp_path / "chunked.tns")
    write_tns(p, t)
    lines = open(p).read().splitlines()
    # sprinkle comments/blanks so some chunks are partially (or fully) noise
    noisy = ["# frostt header", "% matlab-style comment", ""]
    for i, line in enumerate(lines):
        noisy.append(line)
        if i % 7 == 0:
            noisy.append("# interleaved comment")
        if i % 11 == 0:
            noisy.append("")
    open(p, "w").write("\n".join(noisy) + "\n")
    t2 = read_tns(p, chunk_lines=23)  # dozens of chunks
    assert t2.nnz == t.nnz
    got = sorted(map(tuple, np.c_[t2.indices, t2.values].tolist()))
    want = sorted(map(tuple, np.c_[t.indices, t.values].tolist()))
    assert got == want


def test_tns_chunk_sizes_agree(tmp_path):
    t = random_sparse((9, 9, 9), 150, seed=7)
    p = str(tmp_path / "x.tns")
    write_tns(p, t)
    a = read_tns(p, chunk_lines=1)       # degenerate: one line per chunk
    b = read_tns(p, chunk_lines=10**6)   # single chunk
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_array_equal(a.values, b.values)
    assert a.shape == b.shape


def test_profiles_scaled():
    for name, prof in DATASET_PROFILES.items():
        t = make_profile_tensor(name, scale=2e-6, seed=1)
        assert t.nmodes == len(prof.shape)
        assert t.nnz > 0
    tw = make_profile_tensor("twitch", scale=1e-5)
    assert tw.nmodes == 5  # twitch is the 5-mode tensor


def test_tns_gz_roundtrip(tmp_path):
    """.tns.gz paths are compressed/decompressed transparently, both ways,
    and the values round-trip float32-exactly (the %.9g formatter)."""
    t = random_sparse((25, 14, 9), 300, seed=9, distribution="zipf")
    plain = str(tmp_path / "x.tns")
    gz = str(tmp_path / "x.tns.gz")
    write_tns(plain, t)
    write_tns(gz, t)
    with open(gz, "rb") as f:
        assert f.read(2) == b"\x1f\x8b"  # really gzip on disk
    a, b = read_tns(plain), read_tns(gz)
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_array_equal(a.values, b.values)
    np.testing.assert_array_equal(a.indices, t.indices)
    np.testing.assert_array_equal(a.values, t.values)  # exact, not approx


def test_write_tns_chunked_identical(tmp_path):
    """The vectorized writer emits identical text regardless of chunking."""
    t = random_sparse((25, 14, 9), 300, seed=10)
    p1, p2 = str(tmp_path / "a.tns"), str(tmp_path / "b.tns")
    write_tns(p1, t, chunk=7)
    write_tns(p2, t, chunk=10**6)
    assert open(p1).read() == open(p2).read()


def test_read_tns_rejects_int32_overflow(tmp_path):
    p = str(tmp_path / "huge.tns")
    with open(p, "w") as f:
        f.write("1 1 1.0\n")
        f.write(f"{2**31 + 5} 2 2.0\n")  # 1-based coord > int32 max
    with pytest.raises(ValueError, match="int32.*store|store.*int32"):
        read_tns(p)


def test_make_profile_tensor_deterministic():
    a = make_profile_tensor("amazon", scale=2e-6, seed=3)
    b = make_profile_tensor("amazon", scale=2e-6, seed=3)
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_array_equal(a.values, b.values)
    c = make_profile_tensor("amazon", scale=2e-6, seed=4)
    assert not (a.nnz == c.nnz
                and np.array_equal(a.indices, c.indices)
                and np.array_equal(a.values, c.values))


def test_profile_skew_differs_from_uniform():
    """The zipf profiles concentrate mass in the head of each mode, a
    same-geometry uniform draw does not — the property that drives the
    paper's Twitch load-balancing discussion (§5.5)."""
    zipf = make_profile_tensor("twitch", scale=2e-5, seed=0)
    uni = random_sparse(zipf.shape, zipf.nnz, seed=0,
                        distribution="uniform")
    def head_mass(t):
        h = t.mode_histogram(0).astype(np.float64)
        return h[: max(1, h.size // 100)].sum() / h.sum()
    assert head_mass(zipf) > 0.25   # top 1% carries >25% of nonzeros
    assert head_mass(uni) < 0.05    # uniform head is ~1%
    assert head_mass(zipf) > 5 * head_mass(uni)


def test_profile_output_dedup_idempotent():
    """make_profile_tensor output is already deduplicated; a second
    deduplicated() is the identity."""
    t = make_profile_tensor("reddit", scale=1e-6, seed=2)
    d = t.deduplicated()
    assert d.nnz == t.nnz
    np.testing.assert_array_equal(d.indices, t.indices)
    np.testing.assert_array_equal(d.values, t.values)
