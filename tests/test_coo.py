import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.coo import SparseTensor, from_dense, random_sparse, to_dense
from repro.sparse.io import (DATASET_PROFILES, make_profile_tensor, read_tns,
                             write_tns)


def test_basic_container():
    t = random_sparse((10, 8, 6), 50, seed=0)
    assert t.nmodes == 3
    assert t.indices.dtype == np.int32
    assert t.values.dtype == np.float32
    assert (t.indices >= 0).all()
    assert (t.indices < np.array(t.shape)).all()


def test_validation():
    with pytest.raises(ValueError):
        SparseTensor(np.zeros((3, 2), np.int32), np.zeros(2, np.float32), (5, 5))
    with pytest.raises(ValueError):
        SparseTensor(np.array([[9, 0]], np.int32), np.ones(1, np.float32), (5, 5))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_dense_roundtrip(seed):
    rng = np.random.default_rng(seed)
    dense = (rng.random((6, 5, 4)) < 0.3) * rng.normal(size=(6, 5, 4))
    dense = dense.astype(np.float32)
    t = from_dense(dense)
    np.testing.assert_allclose(to_dense(t), dense, rtol=1e-6)


def test_dedup_accumulates():
    ind = np.array([[1, 2], [1, 2], [0, 0]], np.int32)
    val = np.array([1.0, 2.5, -1.0], np.float32)
    t = SparseTensor(ind, val, (3, 3)).deduplicated()
    assert t.nnz == 2
    d = to_dense(t)
    assert d[1, 2] == pytest.approx(3.5)
    assert d[0, 0] == pytest.approx(-1.0)


def test_mode_histogram_counts():
    t = random_sparse((12, 9, 7), 200, seed=3)
    for m in range(3):
        h = t.mode_histogram(m)
        assert h.sum() == t.nnz
        assert h.shape == (t.shape[m],)


def test_sorted_by_mode():
    t = random_sparse((12, 9, 7), 200, seed=4)
    s = t.sorted_by_mode(1)
    assert (np.diff(s.indices[:, 1]) >= 0).all()
    # same multiset of nonzeros
    assert sorted(map(tuple, np.c_[t.indices, t.values].tolist())) == \
        sorted(map(tuple, np.c_[s.indices, s.values].tolist()))


def test_tns_roundtrip(tmp_path):
    t = random_sparse((9, 8, 7, 6, 5), 100, seed=5)
    p = str(tmp_path / "x.tns")
    write_tns(p, t)
    t2 = read_tns(p)
    d1, d2 = to_dense(t), to_dense(t2)
    # shapes may shrink to max index; embed into the larger one
    assert d2.shape <= d1.shape
    np.testing.assert_allclose(d1[tuple(slice(0, s) for s in d2.shape)], d2,
                               atol=1e-5)


def test_tns_roundtrip_multichunk(tmp_path):
    """The chunked reader: a file spanning many read batches, with comment
    and blank lines interleaved, round-trips exactly (same nonzero multiset,
    1-based convention preserved)."""
    t = random_sparse((31, 17, 13), 400, seed=6)
    p = str(tmp_path / "chunked.tns")
    write_tns(p, t)
    lines = open(p).read().splitlines()
    # sprinkle comments/blanks so some chunks are partially (or fully) noise
    noisy = ["# frostt header", "% matlab-style comment", ""]
    for i, line in enumerate(lines):
        noisy.append(line)
        if i % 7 == 0:
            noisy.append("# interleaved comment")
        if i % 11 == 0:
            noisy.append("")
    open(p, "w").write("\n".join(noisy) + "\n")
    t2 = read_tns(p, chunk_lines=23)  # dozens of chunks
    assert t2.nnz == t.nnz
    got = sorted(map(tuple, np.c_[t2.indices, t2.values].tolist()))
    want = sorted(map(tuple, np.c_[t.indices, t.values].tolist()))
    assert got == want


def test_tns_chunk_sizes_agree(tmp_path):
    t = random_sparse((9, 9, 9), 150, seed=7)
    p = str(tmp_path / "x.tns")
    write_tns(p, t)
    a = read_tns(p, chunk_lines=1)       # degenerate: one line per chunk
    b = read_tns(p, chunk_lines=10**6)   # single chunk
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_array_equal(a.values, b.values)
    assert a.shape == b.shape


def test_profiles_scaled():
    for name, prof in DATASET_PROFILES.items():
        t = make_profile_tensor(name, scale=2e-6, seed=1)
        assert t.nmodes == len(prof.shape)
        assert t.nnz > 0
    tw = make_profile_tensor("twitch", scale=1e-5)
    assert tw.nmodes == 5  # twitch is the 5-mode tensor
