"""Row-sorted hierarchical-COO EC (``ec_sorted``) vs the jnp reference:
bit-identity on real partitions, degenerate shapes, the
``segment_sum(indices_are_sorted=True)`` hint, the out-of-core store and
super-shard paths, and the autotune cache v2 -> v3 migration."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.coo import SparseTensor, random_sparse
from repro.core.partition import (ModePartition, block_segment_descriptors,
                                  build_plan, partition_mode)
from repro.kernels import ops as kops
from repro.kernels.ref import mttkrp_local_ref


def _sorted_case(nmodes, rank, seed=0, nnz=400, num_devices=1,
                 replication=1, tile=8, block_p=128, strategy="amped_cdf"):
    shape = tuple([24, 18, 12, 10, 8][:nmodes])
    t = random_sparse(shape, nnz, seed=seed, distribution="zipf")
    part, _, _ = partition_mode(t, 1, num_devices, strategy=strategy,
                                replication=replication, tile=tile,
                                block_p=block_p, layout="sorted")
    rng = np.random.default_rng(seed + 1)
    factors = [jnp.asarray(
        rng.normal(size=(t.shape[w], rank)).astype(np.float32))
        for w in range(nmodes)]
    return t, part, factors


def _run(part, factors, variant, dev=0, num_buffers=2, mode=1):
    kw = dict(mode=mode, num_rows=part.rows_max, tile=part.tile,
              block_p=part.block_p)
    extra = {}
    if variant == "sorted":
        ss, sr = block_segment_descriptors(part.local_rows[dev],
                                           tile=part.tile,
                                           block_p=part.block_p)
        extra = dict(seg_starts=jnp.asarray(ss), seg_rows=jnp.asarray(sr))
    return kops.mttkrp_local(
        jnp.asarray(part.indices[dev]), jnp.asarray(part.values[dev]),
        jnp.asarray(part.local_rows[dev]),
        jnp.asarray(part.block_to_tile[dev]), factors,
        variant=variant, num_buffers=num_buffers, interpret=True,
        tile_mask=jnp.asarray(part.tile_visited[dev]), **kw, **extra)


@pytest.mark.parametrize("nmodes", [3, 4, 5])
@pytest.mark.parametrize("rank", [8, 32])
def test_sorted_matches_ref_bitwise(nmodes, rank):
    """Segmented reduction over the row-sorted layout accumulates the same
    values in the same order as segment_sum — BIT-identical, not approx."""
    _, part, factors = _sorted_case(nmodes, rank, seed=nmodes * 10 + rank)
    assert part.block_layout == "sorted"
    got = np.asarray(_run(part, factors, "sorted"))
    ref = np.asarray(_run(part, factors, "ref"))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("strategy,num_devices,replication", [
    ("amped_cdf", 2, 1),
    ("amped_cdf", 2, 2),
    ("equal_nnz", 1, 1),
])
def test_sorted_multi_device_shards(strategy, num_devices, replication):
    """Every device shard of a multi-device / replicated sorted partition:
    ec_sorted == ref bitwise (replication keeps factor indices global)."""
    _, part, factors = _sorted_case(3, 16, seed=3, num_devices=num_devices,
                                    replication=replication,
                                    strategy=strategy)
    for dev in range(num_devices):
        got = np.asarray(_run(part, factors, "sorted", dev=dev))
        ref = np.asarray(_run(part, factors, "ref", dev=dev))
        np.testing.assert_array_equal(got, ref, err_msg=f"dev {dev}")


@pytest.mark.parametrize("num_buffers", [2, 3, 4])
def test_sorted_num_buffers(num_buffers):
    """DMA-ring depth changes only the prefetch schedule, never the sums."""
    _, part, factors = _sorted_case(3, 16, seed=5)
    got = np.asarray(_run(part, factors, "sorted", num_buffers=num_buffers))
    ref = np.asarray(_run(part, factors, "ref"))
    np.testing.assert_array_equal(got, ref)


def test_ref_hint_bit_identity():
    """``indices_are_sorted=True`` is declarative — on a row-sorted shard the
    hinted segment_sum returns the exact bits of the unhinted call (both
    through ec_rows_ref and through the mttkrp_local rows_sorted plumb)."""
    _, part, factors = _sorted_case(3, 16, seed=7)
    rows = np.asarray(part.local_rows[0])
    assert (np.diff(rows) >= 0).all()  # layout contract
    plain = mttkrp_local_ref(jnp.asarray(part.indices[0]),
                             jnp.asarray(part.values[0]), jnp.asarray(rows),
                             factors, 1, part.rows_max, sorted_rows=False)
    hinted = mttkrp_local_ref(jnp.asarray(part.indices[0]),
                              jnp.asarray(part.values[0]), jnp.asarray(rows),
                              factors, 1, part.rows_max, sorted_rows=True)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(hinted))
    kw = dict(mode=1, num_rows=part.rows_max, tile=part.tile,
              block_p=part.block_p)
    via_ops = kops.mttkrp_local(
        jnp.asarray(part.indices[0]), jnp.asarray(part.values[0]),
        jnp.asarray(rows), jnp.asarray(part.block_to_tile[0]), factors,
        variant="ref", rows_sorted=True, **kw)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(via_ops))


# -- degenerate shapes -------------------------------------------------------

def test_sorted_empty_shard():
    """A device owning no nonzeros (2 groups, every update on one output
    index) must produce exact zeros — all its blocks are padding."""
    ind = np.zeros((50, 3), np.int64)
    ind[:, 1] = np.arange(50) % 7
    ind[:, 2] = np.arange(50) % 5
    t = SparseTensor(ind.astype(np.int32), np.ones(50, np.float32), (3, 7, 5))
    part, _, _ = partition_mode(t, 0, 2, strategy="amped_cdf", replication=1,
                                layout="sorted")
    empty = int(np.argmin(part.nnz_true))
    assert part.nnz_true[empty] == 0
    rng = np.random.default_rng(0)
    factors = [jnp.asarray(rng.normal(size=(s, 8)).astype(np.float32))
               for s in t.shape]
    out = _run(part, factors, "sorted", dev=empty, mode=0)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_sorted_single_segment_spans_blocks():
    """Every nonzero updates ONE output row: a single segment that spans
    multiple full blocks (plus its pad tail) must accumulate across block
    boundaries and write that row once."""
    nnz = 50
    ind = np.zeros((nnz, 3), np.int64)
    ind[:, 0] = np.arange(nnz) % 5
    ind[:, 1] = 2                       # the one output row (mode 1)
    ind[:, 2] = np.arange(nnz) // 5
    t = SparseTensor(ind.astype(np.int32),
                     np.random.default_rng(1).normal(size=nnz)
                     .astype(np.float32), (5, 7, 12))
    part, _, _ = partition_mode(t, 1, 1, tile=8, block_p=16, layout="sorted")
    assert part.indices[0].shape[0] // part.block_p >= 3  # spans >= 3 blocks
    assert len(np.unique(part.local_rows[0])) == 1        # one segment
    rng = np.random.default_rng(2)
    factors = [jnp.asarray(rng.normal(size=(s, 8)).astype(np.float32))
               for s in t.shape]
    got = np.asarray(_run(part, factors, "sorted"))
    ref = np.asarray(_run(part, factors, "ref"))
    np.testing.assert_array_equal(got, ref)
    # exactly one written row
    assert (np.abs(got).sum(axis=1) != 0).sum() == 1


def test_sorted_all_padding_trailing_block():
    """Unequal device loads pad the lighter shard with whole trailing
    blocks; those blocks must be exact no-ops under the segmented walk."""
    # 90 nonzeros on output row 0 vs 6 on row 2: amped_cdf's 2 groups split
    # 90/6, and the light shard pads up to the heavy shard's block cap
    nnz = 96
    ind = np.zeros((nnz, 3), np.int64)
    ind[:90, 1] = 0
    ind[90:, 1] = 2
    ind[:, 0] = np.arange(nnz) % 7
    ind[:, 2] = np.arange(nnz) // 7
    t = SparseTensor(ind.astype(np.int32),
                     np.random.default_rng(6).normal(size=nnz)
                     .astype(np.float32), (7, 4, 16))
    part, _, _ = partition_mode(t, 1, 2, strategy="amped_cdf", replication=1,
                                tile=4, block_p=32, layout="sorted")
    rng = np.random.default_rng(7)
    factors = [jnp.asarray(rng.normal(size=(s, 8)).astype(np.float32))
               for s in t.shape]
    light = int(np.argmin(part.nnz_true))
    assert part.nnz_true[light] > 0  # light but not empty
    blocks = np.asarray(part.values[light]).reshape(-1, part.block_p)
    assert (blocks == 0).all(axis=1).any()  # >= 1 all-padding block
    got = np.asarray(_run(part, factors, "sorted", dev=light))
    ref = np.asarray(_run(part, factors, "ref", dev=light))
    np.testing.assert_array_equal(got, ref)


def test_sorted_segment_boundaries_on_block_edges():
    """Each output row owns EXACTLY block_p nonzeros: every segment starts
    at slot 0 and ends at slot block_p of its own block — the boundary
    edge case of the descriptor walk (no in-block carry, no pad tail)."""
    block_p, rows = 16, 4
    nnz = block_p * rows
    ind = np.zeros((nnz, 3), np.int64)
    ind[:, 1] = np.arange(nnz) // block_p
    ind[:, 0] = np.arange(nnz) % 4
    ind[:, 2] = (np.arange(nnz) % block_p) // 4 + 4 * (np.arange(nnz)
                                                       // (4 * block_p))
    t = SparseTensor(ind.astype(np.int32),
                     np.random.default_rng(3).normal(size=nnz)
                     .astype(np.float32), (4, rows, 16))
    part, _, _ = partition_mode(t, 1, 1, tile=2, block_p=block_p,
                                layout="sorted")
    assert (np.asarray(part.values[0]) != 0).all()  # no padding at all
    ss, sr = block_segment_descriptors(part.local_rows[0], tile=part.tile,
                                       block_p=part.block_p)
    nb = part.indices[0].shape[0] // block_p
    # one segment per block, ending exactly on the block edge
    np.testing.assert_array_equal(ss[:nb, 0], 0)
    np.testing.assert_array_equal(ss[:nb, 1], block_p)
    rng = np.random.default_rng(4)
    factors = [jnp.asarray(rng.normal(size=(s, 8)).astype(np.float32))
               for s in t.shape]
    got = np.asarray(_run(part, factors, "sorted"))
    ref = np.asarray(_run(part, factors, "ref"))
    np.testing.assert_array_equal(got, ref)


# -- out-of-core store + super-shard paths -----------------------------------

def test_sorted_store_plan_bit_identity(tmp_path):
    """build_plan_from_store(layout='sorted') reproduces the in-memory
    sorted partition bit-for-bit, and each streamed super-shard window
    keeps rows nondecreasing and runs ec_sorted == ref bitwise."""
    from repro.store import (TensorStore, build_plan_from_store,
                             split_mode_super_shards, write_store_from_coo)

    t = random_sparse((24, 18, 12), 600, seed=0, distribution="zipf")
    path = str(tmp_path / "s.store")
    write_store_from_coo(t, path, chunk_nnz=128)
    store = TensorStore(path)

    pm = build_plan(t, 2, strategy="amped_cdf", replication=1,
                    layout="sorted")
    ps = build_plan_from_store(store, 2, strategy="amped_cdf",
                               replication=1, layout="sorted")
    for d in range(3):
        a, b = pm.modes[d], ps.modes[d]
        for k in ModePartition.META_FIELDS:
            assert getattr(a, k) == getattr(b, k), k
        assert b.block_layout == "sorted"
        for dev in range(2):
            di, dv, dr = b.device_arrays(dev)
            np.testing.assert_array_equal(di, a.indices[dev])
            np.testing.assert_array_equal(dv, a.values[dev])
            np.testing.assert_array_equal(dr, a.local_rows[dev])

    part = ps.modes[1]
    # a budget small enough to force a real split but above the floors
    nnz_cap_full = part.device_arrays(0)[1].shape[0]
    sp = split_mode_super_shards(
        part, max(64 * 1024, nnz_cap_full * (4 * 3 + 8 + 4) // 2))
    rng = np.random.default_rng(5)
    factors = [jnp.asarray(rng.normal(size=(s, 8)).astype(np.float32))
               for s in t.shape]
    for dev in range(part.num_devices):
        for (t0, t1) in sp.windows[dev]:
            wi, wv, wr, b2t, vis = part.super_shard_arrays(
                dev, t0, t1, nnz_cap=sp.nnz_cap, nblocks=sp.nblocks)
            assert (np.diff(wr) >= 0).all()  # sorted within every window
            ss, sr = block_segment_descriptors(wr, tile=part.tile,
                                               block_p=part.block_p)
            kw = dict(mode=1, num_rows=part.rows_max, tile=part.tile,
                      block_p=part.block_p)
            got = kops.mttkrp_local(
                jnp.asarray(wi), jnp.asarray(wv), jnp.asarray(wr),
                jnp.asarray(b2t), factors, variant="sorted",
                interpret=True, tile_mask=jnp.asarray(vis),
                seg_starts=jnp.asarray(ss), seg_rows=jnp.asarray(sr), **kw)
            ref = kops.mttkrp_local(
                jnp.asarray(wi), jnp.asarray(wv), jnp.asarray(wr),
                jnp.asarray(b2t), factors, variant="ref", **kw)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref),
                                          err_msg=f"dev {dev} [{t0},{t1})")


def test_sorted_end_to_end_als():
    """The 'sorted' preset's full ALS (plan -> compile -> run) produces the
    same factors bitwise as the plain-jnp paper path on the SAME
    row-sorted plan — the whole pipeline, not just one local EC."""
    import repro.api as api

    t = random_sparse((16, 12, 10), 300, seed=2, distribution="zipf")
    base = api.preset("paper", {"rank": 4, "runtime.tol": 0.0,
                                "partition.layout": "sorted",
                                "partition.replication": 1})
    srt = base.with_overrides({"kernel.use_kernel": True,
                               "kernel.variant": "sorted",
                               "kernel.autotune": False})
    outs = {}
    for name, cfg in (("ref", base), ("sorted", srt)):
        solver = api.compile(api.plan(t, cfg), cfg)
        outs[name] = [np.asarray(f) for f in solver.run(2).factors]
    for a, b in zip(outs["ref"], outs["sorted"]):
        np.testing.assert_array_equal(a, b)


# -- autotune cache v2 -> v3 migration ---------------------------------------

def test_autotune_cache_v2_migration(tmp_path, monkeypatch):
    """A v2 cache (dtype slot, no device-kind slot) migrates to v3 with the
    backend segment standing in for the kind; ``xchg_*`` exchange entries
    pass through byte-identical, garbage is dropped, and re-migrating the
    migrated file changes nothing."""
    import json

    import jax

    from repro.kernels import autotune as at

    backend = jax.default_backend()
    grid = {"nnz": 256, "tiles": [8], "block_ps": [64],
            "num_buffers_grid": [2]}
    xchg = {"chunk_rows": 512, "timings": {"c512": 0.5}}
    v2 = {
        "_format": 2,
        f"3m_r8_float32_{backend}_fused": {
            "tile": 8, "block_p": 64, "num_buffers": 2, "grid": grid,
            "timings": {"t8_p64_b2": 1.0}},
        f"4m_r16_bfloat16_{backend}_sorted": {
            "tile": 8, "block_p": 64, "num_buffers": 3, "grid": grid,
            "timings": {"t8_p64_b3": 2.0}},
        "xchg_ring_r16_float32": xchg,
        "not a key": {"tile": 1},
    }
    path = tmp_path / "cache.json"
    path.write_text(json.dumps(v2))
    monkeypatch.setenv(at.ENV_CACHE, str(path))
    at._MEMO.clear()

    loaded = at._load_cache(str(path))
    assert loaded["_format"] == at.CACHE_FORMAT_VERSION
    assert f"3m_r8_float32_{backend}_{backend}_fused" in loaded
    assert f"4m_r16_bfloat16_{backend}_{backend}_sorted" in loaded
    assert loaded["xchg_ring_r16_float32"] == xchg  # untouched
    assert "not a key" not in loaded
    on_disk = json.loads(path.read_text())  # migration persisted
    assert on_disk.get("_format") == at.CACHE_FORMAT_VERSION
    # idempotent: migrating a migrated cache is the identity
    assert at._migrate_cache(on_disk) == {k: v for k, v in on_disk.items()}
