import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.transformer import Model
from repro.models.lm_serve import cache_specs, generate


def test_generate_greedy_deterministic():
    cfg = get_config("granite_8b", "smoke")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    out1 = np.asarray(generate(m, params, prompt, steps=5, cache_len=16))
    out2 = np.asarray(generate(m, params, prompt, steps=5, cache_len=16))
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(out1, out2)
    assert (out1 >= 0).all() and (out1 < cfg.vocab).all()


def test_generate_matches_forward_argmax():
    """First generated token == argmax of the forward logits at the last
    prompt position."""
    cfg = get_config("granite_8b", "smoke")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab)
    full = m.forward(params, prompt)
    want = int(jnp.argmax(full[0, -1]))
    out = np.asarray(generate(m, params, prompt, steps=1, cache_len=12))
    assert out[0, 0] == want


def test_cache_specs_structure_matches_cache():
    """Spec tree must be a prefix-match of the real cache pytree."""
    from jax.sharding import Mesh, PartitionSpec as P
    for arch in ("granite_8b", "jamba15_large", "deepseek_v2_lite",
                 "rwkv6_7b", "whisper_small"):
        cfg = get_config(arch, "smoke")
        m = Model(cfg)
        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        specs = cache_specs(m, mesh, batch=2)
        cache = m.empty_cache(2, 8)
        # same tree structure for the layers part
        a = jax.tree_util.tree_structure(
            specs["layers"], is_leaf=lambda x: isinstance(x, P))
        b = jax.tree_util.tree_structure(cache)
        assert a == b, (arch, a, b)


def test_seq_shard_layout_flag():
    from jax.sharding import Mesh, PartitionSpec as P
    cfg = get_config("granite_8b", "smoke")
    m = Model(cfg)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    auto = cache_specs(m, mesh, batch=2, kv_layout="auto")
    rep = cache_specs(m, mesh, batch=2, kv_layout="replicated_heads")
    k_auto = auto["layers"][0]["mixer"]["k"]
    k_rep = rep["layers"][0]["mixer"]["k"]
    # smoke config kv=2 not divisible by model=1? (1 divides) — just check
    # both are valid PartitionSpecs with rank 5
    assert len(k_auto) == 5 and len(k_rep) == 5
