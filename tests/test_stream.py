"""ShardStreamer contract: bounded residency, LRU eviction, wrap-around
prefetch, async dispatch, and rebalance-driven shard swaps."""
import numpy as np
import pytest

from repro.core import mttkrp as dm
from repro.core.partition import build_plan
from repro.sparse.stream import ShardStreamer


@pytest.fixture(scope="module")
def plan4(small_tensor_4mode):
    return build_plan(small_tensor_4mode, 1)


@pytest.fixture()
def mesh():
    return dm.cp_mesh(1, 1)


def _streamer(plan, mesh, prefetch=1, loads=None):
    s = ShardStreamer(plan, mesh, prefetch=prefetch)
    if loads is not None:
        orig = s._build

        def counting_build(mode):
            loads.append(mode)
            return orig(mode)

        s._build = counting_build
    return s


def test_residency_never_exceeds_prefetch_plus_one(plan4, mesh):
    for prefetch in (0, 1, 2):
        s = _streamer(plan4, mesh, prefetch=prefetch)
        for step in range(12):
            s.get(step % plan4.nmodes)
            assert len(s.resident_modes()) <= prefetch + 1, \
                (prefetch, step, s.resident_modes())


def test_eviction_order_is_lru(plan4, mesh):
    s = _streamer(plan4, mesh, prefetch=1)
    s.get(0)   # resident {0}, pending {1}
    s.get(1)   # 1 integrated + MRU, 2 dispatched, 0 evicted (LRU)
    s.get(2)
    alive = s.resident_modes()
    assert 0 not in alive
    assert 2 in alive          # current mode always alive
    # revisiting a resident mode refreshes it: it must survive the next get
    s.get(3)
    s.get(3)
    assert 3 in s.resident_modes()


def test_wraparound_prefetch(plan4, mesh):
    s = _streamer(plan4, mesh, prefetch=1)
    last = plan4.nmodes - 1
    s.get(last)
    assert 0 in s.resident_modes()  # mode nmodes-1 prefetches mode 0
    loads = []
    s2 = _streamer(plan4, mesh, prefetch=1, loads=loads)
    s2.get(last)
    s2.get(0)   # joins the wrap prefetch (and dispatches mode 1)
    s2._wait(0)
    assert loads[:2] == [last, 0]
    assert loads.count(0) == 1  # the wrap prefetch satisfied get(0): no reload


def test_prefetch_is_async_dispatch(plan4, mesh):
    """get() must dispatch — not synchronously load — the next mode."""
    import threading
    started = threading.Event()
    release = threading.Event()
    loads = []
    s = _streamer(plan4, mesh, prefetch=1, loads=loads)
    orig = s._build

    def slow_build(mode, _orig=orig):
        if mode == 1:
            started.set()
            assert release.wait(timeout=10)
        return _orig(mode)

    s._build = slow_build
    d0 = s.get(0)            # returns while mode 1 is still loading
    assert d0 is not None
    assert started.wait(timeout=10)
    assert 1 not in s._resident and 1 in s.resident_modes()
    release.set()
    s.get(1)                 # joins the in-flight prefetch
    assert 1 in s._resident


def test_zero_prefetch_never_dispatches(plan4, mesh):
    loads = []
    s = _streamer(plan4, mesh, prefetch=0, loads=loads)
    s.get(0)
    s.get(1)
    assert loads == [0, 1]   # strictly on-demand


def test_update_plan_swaps_migrated_modes(plan4, mesh, small_tensor_4mode):
    s = _streamer(plan4, mesh, prefetch=plan4.nmodes)
    before = [s.get(d) for d in range(plan4.nmodes)]
    plan_b = build_plan(small_tensor_4mode, 1)  # fresh arrays, same shapes
    s.update_plan(plan_b, modes=[1])
    after = [s.get(d) for d in range(plan4.nmodes)]
    assert after[0] is before[0]        # untouched modes keep their shards
    assert after[1] is not before[1]    # migrated mode re-placed
    np.testing.assert_array_equal(np.asarray(after[1].values),
                                  plan_b.modes[1].values.reshape(
                                      after[1].values.shape))
