"""ShardStreamer contract: bounded residency, LRU eviction, wrap-around
prefetch, async dispatch, and rebalance-driven shard swaps."""
import numpy as np
import pytest

from repro.core import mttkrp as dm
from repro.core.partition import build_plan
from repro.sparse.stream import ShardStreamer


@pytest.fixture(scope="module")
def plan4(small_tensor_4mode):
    return build_plan(small_tensor_4mode, 1)


@pytest.fixture()
def mesh():
    return dm.cp_mesh(1, 1)


def _streamer(plan, mesh, prefetch=1, loads=None):
    s = ShardStreamer(plan, mesh, prefetch=prefetch)
    if loads is not None:
        orig = s._build

        def counting_build(mode):
            loads.append(mode)
            return orig(mode)

        s._build = counting_build
    return s


def test_residency_never_exceeds_prefetch_plus_one(plan4, mesh):
    for prefetch in (0, 1, 2):
        s = _streamer(plan4, mesh, prefetch=prefetch)
        for step in range(12):
            s.get(step % plan4.nmodes)
            assert len(s.resident_modes()) <= prefetch + 1, \
                (prefetch, step, s.resident_modes())


def test_eviction_order_is_lru(plan4, mesh):
    s = _streamer(plan4, mesh, prefetch=1)
    s.get(0)   # resident {0}, pending {1}
    s.get(1)   # 1 integrated + MRU, 2 dispatched, 0 evicted (LRU)
    s.get(2)
    alive = s.resident_modes()
    assert 0 not in alive
    assert 2 in alive          # current mode always alive
    # revisiting a resident mode refreshes it: it must survive the next get
    s.get(3)
    s.get(3)
    assert 3 in s.resident_modes()


def test_wraparound_prefetch(plan4, mesh):
    s = _streamer(plan4, mesh, prefetch=1)
    last = plan4.nmodes - 1
    s.get(last)
    assert 0 in s.resident_modes()  # mode nmodes-1 prefetches mode 0
    loads = []
    s2 = _streamer(plan4, mesh, prefetch=1, loads=loads)
    s2.get(last)
    s2.get(0)   # joins the wrap prefetch (and dispatches mode 1)
    s2._wait(0)
    assert loads[:2] == [last, 0]
    assert loads.count(0) == 1  # the wrap prefetch satisfied get(0): no reload


def test_prefetch_is_async_dispatch(plan4, mesh):
    """get() must dispatch — not synchronously load — the next mode."""
    import threading
    started = threading.Event()
    release = threading.Event()
    loads = []
    s = _streamer(plan4, mesh, prefetch=1, loads=loads)
    orig = s._build

    def slow_build(mode, _orig=orig):
        if mode == 1:
            started.set()
            assert release.wait(timeout=10)
        return _orig(mode)

    s._build = slow_build
    d0 = s.get(0)            # returns while mode 1 is still loading
    assert d0 is not None
    assert started.wait(timeout=10)
    assert 1 not in s._resident and 1 in s.resident_modes()
    release.set()
    s.get(1)                 # joins the in-flight prefetch
    assert 1 in s._resident


def test_zero_prefetch_never_dispatches(plan4, mesh):
    loads = []
    s = _streamer(plan4, mesh, prefetch=0, loads=loads)
    s.get(0)
    s.get(1)
    assert loads == [0, 1]   # strictly on-demand


def test_update_plan_swaps_migrated_modes(plan4, mesh, small_tensor_4mode):
    s = _streamer(plan4, mesh, prefetch=plan4.nmodes)
    before = [s.get(d) for d in range(plan4.nmodes)]
    plan_b = build_plan(small_tensor_4mode, 1)  # fresh arrays, same shapes
    s.update_plan(plan_b, modes=[1])
    after = [s.get(d) for d in range(plan4.nmodes)]
    assert after[0] is before[0]        # untouched modes keep their shards
    assert after[1] is not before[1]    # migrated mode re-placed
    np.testing.assert_array_equal(np.asarray(after[1].values),
                                  plan_b.modes[1].values.reshape(
                                      after[1].values.shape))


def test_close_shuts_down_executor_and_cancels_pending(plan4, mesh):
    """Regression: the streamer never shut down its ThreadPoolExecutor —
    in-flight prefetch futures leaked past solver teardown and could touch
    freed plan state. close() must cancel queued work, join the in-flight
    build, and leave the streamer unusable."""
    import threading

    release = threading.Event()
    s = _streamer(plan4, mesh, prefetch=2)
    orig = s._build

    def slow_build(mode, _orig=orig):
        if mode != 0:
            assert release.wait(timeout=10)
        return _orig(mode)

    s._build = slow_build
    s.get(0)                      # dispatches mode 1 (blocked in executor)
    s._dispatch(2)                # queued behind mode 1 → cancellable
    release.set()
    s.close()
    assert not s._pending and not s._resident
    assert s._pool._shutdown      # executor joined, thread gone
    with pytest.raises(RuntimeError, match="closed"):
        s.get(0)
    s.close()                     # idempotent


def test_close_joins_inflight_build(plan4, mesh):
    """close() must WAIT for a prefetch that is already executing — a
    background device_put racing teardown is exactly the leak."""
    import threading

    started = threading.Event()
    release = threading.Event()
    done = []
    s = _streamer(plan4, mesh, prefetch=1)
    orig = s._build

    def slow_build(mode, _orig=orig):
        if mode == 1:
            started.set()
            assert release.wait(timeout=10)
            done.append(mode)
        return _orig(mode)

    s._build = slow_build
    s.get(0)                      # dispatches mode 1
    assert started.wait(timeout=10)
    closer = threading.Thread(target=s.close)
    closer.start()
    assert closer.is_alive()      # close blocks on the in-flight build
    release.set()
    closer.join(timeout=10)
    assert not closer.is_alive() and done == [1]


def test_solver_close_and_context_manager(small_tensor_4mode):
    import repro.api as api

    cfg = api.paper({"rank": 4, "runtime.tol": 0.0})
    plan = api.plan(small_tensor_4mode, cfg)
    with api.compile(plan, cfg) as solver:
        solver.sweep()
        streamer = solver.streamer
    assert streamer._closed       # context exit closed the streamer
    with pytest.raises(RuntimeError, match="closed"):
        solver.sweep()
    solver.close()                # idempotent after exit


def test_pending_prefetch_counts_against_bound(plan4, mesh):
    """Regression: an in-flight prefetch holds device memory just like a
    resident shard — the prefetch+1 bound must count resident + pending at
    every instant, including the moment a new key is added (room is made
    BEFORE the add, never by evicting after)."""
    peaks = []
    s = _streamer(plan4, mesh, prefetch=1)
    orig_add = s._track_add

    def checking_add(key, _orig=orig_add):
        # called just before the key enters _resident/_pending: the bound
        # must already have room for it
        peaks.append(len(s._resident) + len(s._pending) + 1)
        _orig(key)

    s._track_add = checking_add
    for step in range(12):
        s.get(step % plan4.nmodes)
        assert len(s._resident) + len(s._pending) <= 2
    assert peaks and max(peaks) <= 2
    s.close()


def test_superseded_prefetch_settled_on_divergence(plan4, mesh):
    """When the access pattern diverges from the prefetch chain, the stale
    pending shard must be settled and discarded — it must not survive as a
    third resident alongside the new current+next pair."""
    import threading

    release = threading.Event()
    s = _streamer(plan4, mesh, prefetch=1)
    orig = s._build

    def gated(mode, _orig=orig):
        if mode == 1:
            assert release.wait(timeout=10)
        return _orig(mode)

    s._build = gated
    s.get(0)                       # pending {1}, blocked in the worker
    t = threading.Timer(0.2, release.set)
    t.start()
    s.get(2)                       # diverges: the mode-1 prefetch is stale
    t.join()
    assert set(s._resident) | set(s._pending) <= {2, 3}
    assert len(s._resident) + len(s._pending) <= 2
    s.close()


def test_settle_cancels_queued_prefetch(plan4, mesh):
    """A superseded prefetch still queued behind the worker must be
    cancelled outright — its build never runs."""
    import threading

    release = threading.Event()
    loads = []
    s = _streamer(plan4, mesh, prefetch=2, loads=loads)
    orig = s._build

    def gated(mode, _orig=orig):
        if mode == 1:
            assert release.wait(timeout=10)
        return _orig(mode)

    s._build = gated
    s.get(0)           # resident {0}, pending {1} blocked in the worker
    s._dispatch(2)     # queued behind mode 1 -> still cancellable
    s._settle(2)
    release.set()
    s._wait(1)
    s.close()
    assert 2 not in loads


def test_update_plan_cancels_stale_pending(plan4, mesh, small_tensor_4mode):
    """update_plan must settle a stale mode's pending prefetch BEFORE the
    plan pointer moves — the replacement shards must come from the new
    plan, never the old one."""
    from repro.core.partition import build_plan as bp

    s = _streamer(plan4, mesh, prefetch=plan4.nmodes)
    plans_seen = []
    orig = s._build

    def recording_build(mode, _orig=orig):
        plans_seen.append((mode, s.plan))
        return _orig(mode)

    s._build = recording_build
    s.get(0)                      # dispatches mode 1 under the old plan
    plan_b = bp(small_tensor_4mode, 1)
    s.update_plan(plan_b, modes=[1])
    arrays = s._wait(1)
    # the build satisfying the wait ran under the NEW plan (the old-plan
    # prefetch, if it ever ran, was settled and discarded inside
    # update_plan before the plan pointer moved)
    last_mode1_plan = [p for m, p in plans_seen if m == 1][-1]
    assert last_mode1_plan is plan_b
    np.testing.assert_array_equal(
        np.asarray(arrays.values),
        plan_b.modes[1].values.reshape(arrays.values.shape))
    s.close()
